"""Campaign engine: interleaved cursors, checkpoint/resume, reporting.

All tests drive synthetic evaluators — no XLA compiles.  The
load-bearing invariants:

  * a campaign's per-cell reports are bit-identical to the sequential
    per-cell blocking driver (``run_tuning`` / ``run_sensitivity``),
    whatever strategy is selected;
  * an interrupted campaign resumes from ``results/campaign/``-style
    checkpoints without re-evaluating any completed (absorbed) trial,
    and converges to the same reports;
  * stale or corrupt checkpoints are discarded, never trusted;
    checkpoints from a different strategy are discarded with a warning,
    and PR-2-era (version-1) tree checkpoints are migrated in place.
"""
import dataclasses
import json
import threading

import pytest

from repro.core import report
from repro.core.campaign import (CHECKPOINT_VERSION, Campaign, CellSpec,
                                 enumerate_cells, parse_cells,
                                 tuning_fingerprint)
from repro.core.params import default_config
from repro.core.sensitivity import run_sensitivity
from repro.core.tree import run_tuning
from repro.core.trial import TrialResult, TrialRunner

CELLS = [CellSpec("smollm-135m", "train_4k"),
         CellSpec("smollm-135m", "prefill_32k"),
         CellSpec("glm4-9b", "train_4k"),
         CellSpec("xlstm-1.3b", "decode_32k")]


def baseline_factory(spec):
    return default_config(shard_strategy="fsdp_tp", attn_impl="pallas")


def surface(wl, rt):
    """Deterministic per-cell cost surface with one crash region."""
    if wl.arch == "glm4-9b" and rt.remat_policy == "full":
        return TrialResult(cost_s=float("inf"), crashed=True)
    c = 100.0 + 3.0 * len(wl.arch)
    if rt.compute_dtype == "bfloat16":
        c *= 0.7
    if rt.shard_strategy == "tp":
        c *= 0.9
    if rt.shard_strategy == "fsdp":
        c *= 1.1
    if rt.remat_policy == "none":
        c *= 1.2 if wl.arch == "glm4-9b" else 0.85
    if rt.microbatches == 2:
        c *= 0.97
    if rt.kv_cache_dtype == "int8":
        c *= 0.8
    if rt.attn_block_q == 256:
        c *= 0.92
    return TrialResult(cost_s=round(c, 6))


class CountingSurface:
    def __init__(self, fail_after=None, fn=None):
        self.calls = []
        self.lock = threading.Lock()
        self.fail_after = fail_after
        self.fn = fn or surface

    def __call__(self, wl, rt):
        with self.lock:
            self.calls.append((wl.key(), rt.as_dict()))
            if self.fail_after is not None \
                    and len(self.calls) > self.fail_after:
                raise KeyboardInterrupt("simulated kill")
        return self.fn(wl, rt)


def sequential_reference():
    """The per-cell loop the campaign must reproduce bit for bit."""
    out = {}
    for spec in CELLS:
        runner = TrialRunner(spec.workload(), surface)
        out[spec.key()] = run_tuning(runner, baseline_factory(spec),
                                     threshold=0.05)
    return out


def test_campaign_matches_sequential_loop(tmp_path):
    camp = Campaign(CELLS, threshold=0.05, evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path, max_workers=4)
    reports = camp.run()
    ref = sequential_reference()
    assert list(reports) == [c.key() for c in CELLS]
    for key, rep in reports.items():
        # full bit-identity: log, n_trials, accepted, final_config
        assert rep.__dict__ == ref[key].__dict__
    assert camp.last_stats["evaluated_trials"] \
        == sum(r.n_trials for r in ref.values())


def test_campaign_without_checkpoints():
    camp = Campaign(CELLS, threshold=0.05, evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=None, max_workers=2)
    reports = camp.run()
    ref = sequential_reference()
    for key, rep in reports.items():
        assert tuning_fingerprint(rep) == tuning_fingerprint(ref[key])


def test_campaign_resume_replays_everything(tmp_path):
    camp = Campaign(CELLS, evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path)
    first = camp.run()
    counting = CountingSurface()
    camp2 = Campaign(CELLS, evaluator=counting,
                     baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path)
    second = camp2.run()
    assert counting.calls == []          # nothing re-paid
    assert camp2.last_stats["evaluated_trials"] == 0
    assert camp2.last_stats["replayed_trials"] \
        == camp.last_stats["trials"]
    for key in first:
        assert first[key].__dict__ == second[key].__dict__


def test_interrupted_campaign_resumes_without_repaying(tmp_path):
    """Kill mid-campaign, resume: no absorbed trial is re-evaluated and
    the final reports are identical to the uninterrupted run."""
    killer = CountingSurface(fail_after=9)
    camp = Campaign(CELLS, evaluator=killer,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path, max_workers=2)
    with pytest.raises(KeyboardInterrupt):
        camp.run()
    # what the checkpoints say is already absorbed
    absorbed = []
    for spec in CELLS:
        path = tmp_path / f"{spec.key()}.json"
        if path.exists():
            d = json.loads(path.read_text())
            absorbed += [(d["cell"], e["config"]) for e in d["log"]]
    assert absorbed                       # the kill landed mid-campaign
    resumer = CountingSurface()
    camp2 = Campaign(CELLS, evaluator=resumer,
                     baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path, max_workers=2)
    reports = camp2.run()
    # no completed trial was re-paid
    re_evaluated = {(k, json.dumps(c, sort_keys=True))
                    for k, c in resumer.calls}
    absorbed_set = {(k, json.dumps(c, sort_keys=True))
                    for k, c in absorbed}
    assert not re_evaluated & absorbed_set
    assert camp2.last_stats["replayed_trials"] == len(absorbed)
    ref = sequential_reference()
    for key, rep in reports.items():
        assert rep.__dict__ == ref[key].__dict__


def test_stale_checkpoint_discarded(tmp_path):
    """A checkpoint written under a different threshold (or tree) must
    not be replayed — the accept/reject decisions would be wrong."""
    Campaign(CELLS[:1], threshold=0.05, evaluator=surface,
             baseline_factory=baseline_factory,
             checkpoint_dir=tmp_path).run()
    counting = CountingSurface()
    camp = Campaign(CELLS[:1], threshold=0.10, evaluator=counting,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path)
    rep = camp.run()[CELLS[0].key()]
    assert camp.last_stats["replayed_trials"] == 0
    assert len(counting.calls) == rep.n_trials


def test_corrupt_checkpoint_discarded(tmp_path):
    spec = CELLS[0]
    (tmp_path / f"{spec.key()}.json").write_text("{not json")
    camp = Campaign([spec], evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path)
    rep = camp.run()[spec.key()]
    runner = TrialRunner(spec.workload(), surface)
    ref = run_tuning(runner, baseline_factory(spec), threshold=0.05)
    assert rep.__dict__ == ref.__dict__


def test_discard_checkpoints(tmp_path):
    camp = Campaign(CELLS[:2], evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path)
    camp.run()
    assert any(tmp_path.glob("*.json"))
    camp.discard_checkpoints()
    assert not list(tmp_path.glob("smollm*.json"))


def test_duplicate_cells_rejected():
    with pytest.raises(ValueError):
        Campaign([CELLS[0], CELLS[0]], evaluator=surface)


# -------------------------------------------------------- cell plumbing
def test_enumerate_cells_applicability():
    cells = enumerate_cells()
    keys = {(c.arch, c.shape) for c in cells}
    # long_500k only for sub-quadratic families (dryrun's skip rule)
    assert ("xlstm-1.3b", "long_500k") in keys
    assert ("zamba2-7b", "long_500k") in keys
    assert ("glm4-9b", "long_500k") not in keys
    assert ("glm4-9b", "train_4k") in keys
    assert all(not c.multi_pod for c in cells)
    both = enumerate_cells(archs=["smollm-135m"], shapes=["train_4k"],
                           meshes=(False, True))
    assert [c.multi_pod for c in both] == [False, True]


def test_parse_cells():
    cells = parse_cells("smollm-135m:train_4k, glm4-9b:train_4k:pod,"
                        "xlstm-1.3b:long_500k:multipod")
    assert cells[0] == CellSpec("smollm-135m", "train_4k")
    assert cells[1] == CellSpec("glm4-9b", "train_4k", False)
    assert cells[2] == CellSpec("xlstm-1.3b", "long_500k", True)
    with pytest.raises(ValueError):
        parse_cells("smollm-135m")                      # no shape
    with pytest.raises(KeyError):
        parse_cells("no-such-arch:train_4k")
    with pytest.raises(ValueError):
        parse_cells("glm4-9b:long_500k")                # not applicable
    with pytest.raises(ValueError):
        parse_cells("")


# --------------------------------------------------- strategy campaigns
def sens_fingerprint(rep):
    return json.dumps(dataclasses.asdict(rep), sort_keys=True,
                      default=str)


def test_sensitivity_campaign_matches_run_sensitivity(tmp_path):
    """Acceptance: SensitivityCursor through Campaign reproduces
    run_sensitivity's KnobImpact table exactly, per cell."""
    camp = Campaign(CELLS, strategy="sensitivity", evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path, max_workers=4)
    reports = camp.run()
    assert list(reports) == [c.key() for c in CELLS]
    for spec in CELLS:
        runner = TrialRunner(spec.workload(), surface)
        ref = run_sensitivity(runner, baseline_factory(spec))
        assert sens_fingerprint(reports[spec.key()]) \
            == sens_fingerprint(ref)
        assert reports[spec.key()].table() == ref.table()


def test_sensitivity_campaign_kill_and_resume(tmp_path):
    """Satellite: kill mid-campaign under the sensitivity strategy,
    resume — no absorbed trial re-paid, identical final reports."""
    killer = CountingSurface(fail_after=6)
    camp = Campaign(CELLS, strategy="sensitivity", evaluator=killer,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path, max_workers=2)
    with pytest.raises(KeyboardInterrupt):
        camp.run()
    absorbed = []
    for spec in CELLS:
        path = tmp_path / f"{spec.key()}.json"
        if path.exists():
            d = json.loads(path.read_text())
            assert d["strategy"] == "sensitivity"
            absorbed += [(d["cell"], e["config"]) for e in d["log"]]
    assert absorbed                       # the kill landed mid-campaign
    resumer = CountingSurface()
    camp2 = Campaign(CELLS, strategy="sensitivity", evaluator=resumer,
                     baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path, max_workers=2)
    reports = camp2.run()
    re_evaluated = {(k, json.dumps(c, sort_keys=True))
                    for k, c in resumer.calls}
    absorbed_set = {(k, json.dumps(c, sort_keys=True))
                    for k, c in absorbed}
    assert not re_evaluated & absorbed_set
    assert camp2.last_stats["replayed_trials"] == len(absorbed)
    for spec in CELLS:
        ref = run_sensitivity(TrialRunner(spec.workload(), surface),
                              baseline_factory(spec))
        assert sens_fingerprint(reports[spec.key()]) \
            == sens_fingerprint(ref)


def test_random_campaign_matches_direct_drive(tmp_path):
    from repro.core.strategy import drive, make_cursor
    camp = Campaign(CELLS, strategy="random",
                    strategy_options={"seed": 7, "budget": 5},
                    evaluator=surface, baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path)
    reports = camp.run()
    for spec in CELLS:
        ref = drive(make_cursor("random",
                                TrialRunner(spec.workload(), surface),
                                baseline_factory(spec),
                                options={"seed": 7, "budget": 5}))
        assert reports[spec.key()].__dict__ == ref.__dict__
    # resume replays everything
    counting = CountingSurface()
    camp2 = Campaign(CELLS, strategy="random",
                     strategy_options={"seed": 7, "budget": 5},
                     evaluator=counting,
                     baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path)
    camp2.run()
    assert counting.calls == []
    # different seed -> different signature -> silent fresh start
    camp3 = Campaign(CELLS[:1], strategy="random",
                     strategy_options={"seed": 8, "budget": 5},
                     evaluator=surface, baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path)
    camp3.run()
    assert camp3.last_stats["replayed_trials"] == 0


def test_stale_strategy_checkpoint_discarded_with_warning(tmp_path):
    """Satellite: a checkpoint written by a different strategy must be
    discarded with a warning, never crash resume."""
    Campaign(CELLS[:1], strategy="sensitivity", evaluator=surface,
             baseline_factory=baseline_factory,
             checkpoint_dir=tmp_path).run()
    counting = CountingSurface()
    with pytest.warns(UserWarning, match="stale checkpoint"):
        camp = Campaign(CELLS[:1], strategy="tree", evaluator=counting,
                        baseline_factory=baseline_factory,
                        checkpoint_dir=tmp_path)
        rep = camp.run()[CELLS[0].key()]
    assert camp.last_stats["replayed_trials"] == 0
    assert len(counting.calls) == rep.n_trials
    ref = run_tuning(TrialRunner(CELLS[0].workload(), surface),
                     baseline_factory(CELLS[0]), threshold=0.05)
    assert rep.__dict__ == ref.__dict__


def test_v1_tree_checkpoint_migration_shim(tmp_path):
    """PR-2-era checkpoints (version 1, no strategy field) must resume
    under the tree strategy without re-evaluating anything."""
    camp = Campaign(CELLS, evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path)
    first = camp.run()
    for spec in CELLS:       # rewrite as PR-2-era layout
        path = tmp_path / f"{spec.key()}.json"
        d = json.loads(path.read_text())
        assert d["version"] == CHECKPOINT_VERSION
        d["version"] = 1
        del d["strategy"], d["strategy_version"]
        path.write_text(json.dumps(d))
    counting = CountingSurface()
    camp2 = Campaign(CELLS, evaluator=counting,
                     baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path)
    second = camp2.run()
    assert counting.calls == []          # nothing re-paid
    assert camp2.last_stats["evaluated_trials"] == 0
    for key in first:
        assert first[key].__dict__ == second[key].__dict__
    # ...but a v1 checkpoint under a non-tree strategy is stale
    for spec in CELLS:
        path = tmp_path / f"{spec.key()}.json"
        d = json.loads(path.read_text())
        d["version"] = 1
        d.pop("strategy", None), d.pop("strategy_version", None)
        path.write_text(json.dumps(d))
    with pytest.warns(UserWarning, match="stale checkpoint"):
        camp3 = Campaign(CELLS, strategy="random", evaluator=surface,
                         baseline_factory=baseline_factory,
                         checkpoint_dir=tmp_path)
        camp3.run()
    assert camp3.last_stats["replayed_trials"] == 0


def test_sensitivity_campaign_markdown(tmp_path):
    reports = Campaign(CELLS, strategy="sensitivity", evaluator=surface,
                       baseline_factory=baseline_factory,
                       checkpoint_dir=tmp_path).run()
    md = report.strategy_markdown(reports)
    assert "sensitivity impact per cell" in md
    assert "| knob (Spark analogue) |" in md
    cell_md = report.cell_markdown(next(iter(reports.values())))
    assert "### Sensitivity:" in cell_md and "mean abs %" in cell_md


def test_campaign_markdown(tmp_path):
    reports = Campaign(CELLS, evaluator=surface,
                       baseline_factory=baseline_factory,
                       checkpoint_dir=tmp_path).run()
    md = report.campaign_markdown(reports)
    assert "| arch |" in md
    assert "smollm-135m" in md and "xlstm-1.3b" in md
    assert f"cells tuned: {len(CELLS)}" in md
    assert "geometric-mean speedup" in md


# ------------------------------------------------- history + warm-start
# The PR-2 bench batch: cells of the same shape kind share one best
# tree outcome on the synthetic fabric surface — the structure
# warm-starting exploits.
FCELLS = [CellSpec("smollm-135m", "train_4k"),
          CellSpec("smollm-135m", "prefill_32k"),
          CellSpec("xlstm-1.3b", "prefill_32k"),
          CellSpec("xlstm-1.3b", "decode_32k")]


def fsurface(wl, rt):
    from benchmarks.fabric_surface import surface_cost
    return surface_cost(wl, rt)


def trials_to_best(rep, target_config):
    """1-based count of evaluated trials until ``target_config`` first
    appears in the log; inf if it never does."""
    for i, e in enumerate(rep.log):
        if e["config"] == target_config:
            return i + 1
    return float("inf")


def test_campaign_writes_history_by_default(tmp_path):
    from repro.core.history import TrialHistory
    camp = Campaign(FCELLS, evaluator=fsurface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path)
    reports = camp.run()
    hist = TrialHistory(tmp_path / "history.jsonl")
    assert hist.n_records() == sum(r.n_trials for r in reports.values())
    assert sorted(hist.cells()) == sorted(c.key() for c in FCELLS)
    # resume replays, so nothing is re-emitted
    Campaign(FCELLS, evaluator=fsurface,
             baseline_factory=baseline_factory,
             checkpoint_dir=tmp_path).run()
    assert hist.n_records() == sum(r.n_trials for r in reports.values())
    # history=False opts out
    camp2 = Campaign(FCELLS, evaluator=fsurface,
                     baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path / "nohist", history=False)
    camp2.run()
    assert not (tmp_path / "nohist" / "history.jsonl").exists()


def test_warm_start_reaches_best_in_fewer_trials(tmp_path):
    """Acceptance: the warm-started arm reaches the cold best config in
    strictly fewer evaluated trials on >= 2 of the 4 batch cells."""
    from repro.core.history import TrialHistory
    cold = Campaign(FCELLS, evaluator=fsurface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path / "cold").run()
    hist = TrialHistory(tmp_path / "cold" / "history.jsonl")
    warm_camp = Campaign(FCELLS, evaluator=fsurface,
                         baseline_factory=baseline_factory,
                         checkpoint_dir=tmp_path / "warm",
                         history=hist, warm_start=True)
    warm = warm_camp.run()
    assert warm_camp.last_stats["warmstarted_cells"] >= 2
    improved = sum(
        trials_to_best(warm[c.key()], cold[c.key()].final_config)
        < trials_to_best(cold[c.key()], cold[c.key()].final_config)
        for c in FCELLS)
    assert improved >= 2
    # warm-start trials still respect the <=10-run budget
    assert all(r.n_trials <= 10 for r in warm.values())


def test_warm_start_resume_uses_checkpointed_seeds(tmp_path):
    """An interrupted warm-started campaign must replay against the
    seeds its checkpoint recorded, even if the history has since grown
    and a fresh query would return different seeds."""
    import shutil
    from repro.core.history import TrialHistory
    Campaign(FCELLS, evaluator=fsurface,
             baseline_factory=baseline_factory,
             checkpoint_dir=tmp_path / "cold").run()
    h_main = tmp_path / "h_main.jsonl"
    h_ref = tmp_path / "h_ref.jsonl"
    shutil.copy(tmp_path / "cold" / "history.jsonl", h_main)
    shutil.copy(tmp_path / "cold" / "history.jsonl", h_ref)
    # uninterrupted warm reference
    ref = Campaign(FCELLS, evaluator=fsurface,
                   baseline_factory=baseline_factory,
                   checkpoint_dir=tmp_path / "ref",
                   history=TrialHistory(h_ref), warm_start=True).run()
    # interrupted warm run
    killer = CountingSurface(fail_after=8, fn=fsurface)
    camp = Campaign(FCELLS, evaluator=killer,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path / "warm",
                    history=TrialHistory(h_main), warm_start=True,
                    max_workers=2)
    with pytest.raises(KeyboardInterrupt):
        camp.run()
    absorbed = []
    with_seeds = 0
    for spec in FCELLS:
        path = tmp_path / "warm" / f"{spec.key()}.json"
        if path.exists():
            d = json.loads(path.read_text())
            absorbed += [(d["cell"], e["config"]) for e in d["log"]]
            with_seeds += "warmstart" in d
    assert absorbed and with_seeds
    # the history grows under the campaign: a fresh query would now
    # return different seeds for every cell
    poison = TrialHistory(h_main)
    best = dict(next(iter(poison.records())))
    best["cell"] = "glm4-9b__train_4k__pod"
    best["arch"], best["shape"] = "glm4-9b", "train_4k"
    best["cost_s"] = 0.001
    best["config"] = default_config(
        shard_strategy="fsdp", attn_impl="pallas").as_dict()
    poison.append(best)
    resumer = CountingSurface(fn=fsurface)
    camp2 = Campaign(FCELLS, evaluator=resumer,
                     baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path / "warm",
                     history=poison, warm_start=True, max_workers=2)
    resumed = camp2.run()
    re_evaluated = {(k, json.dumps(c, sort_keys=True))
                    for k, c in resumer.calls}
    absorbed_set = {(k, json.dumps(c, sort_keys=True))
                    for k, c in absorbed}
    assert not re_evaluated & absorbed_set
    for spec in FCELLS:
        assert resumed[spec.key()].__dict__ == ref[spec.key()].__dict__


def test_warm_start_invalidates_cold_checkpoints(tmp_path):
    """Turning warm-start on changes a seeded cell's walk, so a cold
    checkpoint must not be replayed into it; a cell whose query yields
    no seeds keeps its cold signature and still replays."""
    from repro.core.history import TrialHistory
    cold_camp = Campaign(FCELLS, evaluator=fsurface,
                         baseline_factory=baseline_factory,
                         checkpoint_dir=tmp_path)
    cold_camp.run()
    warm_camp = Campaign(FCELLS, evaluator=fsurface,
                         baseline_factory=baseline_factory,
                         checkpoint_dir=tmp_path, warm_start=True)
    warm_camp.run()
    # seeds existed for every cell -> all cold checkpoints discarded
    assert warm_camp.last_stats["replayed_trials"] == 0
    # single cell, empty foreign history -> no seeds -> cold replay
    solo = tmp_path / "solo"
    Campaign(FCELLS[:1], evaluator=fsurface,
             baseline_factory=baseline_factory,
             checkpoint_dir=solo).run()
    counting = CountingSurface()
    camp = Campaign(FCELLS[:1], evaluator=counting,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=solo, warm_start=True)
    camp.run()
    assert counting.calls == []
    assert camp.last_stats["replayed_trials"] > 0


def test_warm_start_stored_empty_seed_list_wins_on_resume(tmp_path):
    """A checkpointed ``"warmstart": []`` is a stored decision: even if
    the history has since grown and a fresh query would now return
    seeds, resume must honor the empty list and replay — not discard
    the checkpoint and re-pay the walk."""
    from repro.core.history import TrialHistory
    solo = tmp_path / "solo"
    camp = Campaign(FCELLS[:1], evaluator=fsurface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=solo, warm_start=True)
    camp.run()                           # no neighbors -> no seeds
    ck = json.loads((solo / f"{FCELLS[0].key()}.json").read_text())
    assert ck["warmstart"] == []
    # the history grows: a neighbor cell appears with a great config
    hist = TrialHistory(solo / "history.jsonl")
    rec = dict(next(iter(hist.records())))
    rec.update(cell=FCELLS[2].key(), arch=FCELLS[2].arch,
               shape=FCELLS[2].shape, cost_s=0.001,
               config=default_config(shard_strategy="fsdp_tp",
                                     attn_impl="pallas",
                                     compute_dtype="bfloat16").as_dict())
    hist.append(rec)
    assert hist.warmstart_configs(FCELLS[0].arch, FCELLS[0].shape)
    counting = CountingSurface(fn=fsurface)
    camp2 = Campaign(FCELLS[:1], evaluator=counting,
                     baseline_factory=baseline_factory,
                     checkpoint_dir=solo, warm_start=True)
    camp2.run()
    assert counting.calls == []          # stored [] won; full replay
    assert camp2.last_stats["replayed_trials"] > 0


def test_warm_start_without_history_rejected():
    with pytest.raises(ValueError, match="warm_start"):
        Campaign(CELLS, evaluator=surface, checkpoint_dir=None,
                 warm_start=True)


# ----------------------------------------------- --fresh (launch/tune)
def test_fresh_respects_per_strategy_dirs(tmp_path, monkeypatch):
    """Satellite: ``--fresh`` under ``--strategy random`` clears only
    the random subdirectory's checkpoints (and leases) — the tree
    strategy's checkpoints in the parent dir survive untouched."""
    import repro.core.campaign as campaign_mod
    from repro.launch import tune
    monkeypatch.setattr(campaign_mod, "CAMPAIGN_DIR", tmp_path / "camp")
    monkeypatch.setattr(tune, "RESULTS_DIR", tmp_path / "tuning")
    cells = CELLS[:2]
    tune.tune_campaign(cells, evaluator=surface)
    tune.tune_campaign(cells, strategy="random",
                       strategy_options={"budget": 3, "seed": 1},
                       evaluator=surface)
    tree_dir, rand_dir = tmp_path / "camp", tmp_path / "camp" / "random"
    assert all((tree_dir / f"{c.key()}.json").exists() for c in cells)
    assert all((rand_dir / f"{c.key()}.json").exists() for c in cells)
    # a crashed worker's leftover lease in the random dir
    (rand_dir / "leases").mkdir()
    (rand_dir / "leases" / f"{cells[0].key()}.lease").write_text("{}")
    tree_bytes = {c.key(): (tree_dir / f"{c.key()}.json").read_bytes()
                  for c in cells}
    counting = CountingSurface()
    tune.tune_campaign(cells, strategy="random",
                       strategy_options={"budget": 3, "seed": 1},
                       evaluator=counting, fresh=True)
    assert counting.calls                # random really re-tuned
    assert not (rand_dir / "leases"
                / f"{cells[0].key()}.lease").exists()
    for c in cells:                      # tree state untouched
        assert (tree_dir / f"{c.key()}.json").read_bytes() \
            == tree_bytes[c.key()]
    counting2 = CountingSurface()
    tune.tune_campaign(cells, evaluator=counting2)
    assert counting2.calls == []         # tree still replays fully


def test_fresh_rejected_outside_campaign_mode(capsys):
    from repro.launch import tune
    with pytest.raises(SystemExit):
        tune.main(["--arch", "smollm-135m", "--shape", "train_4k",
                   "--fresh"])
    assert "--fresh only applies" in capsys.readouterr().err
    with pytest.raises(SystemExit):
        tune.main(["--worker", "--cells", "smollm-135m:train_4k",
                   "--fresh"])


# ------------------------------------------------- hardened campaigns
def test_fault_free_hardened_campaign_bit_identical(tmp_path):
    """Regression (acceptance): turning every hardening layer on costs
    nothing on a fault-free campaign — reports, logs, budgets and
    checkpoints stay bit-identical to the unhardened run, and the
    stats payload carries no health block."""
    from repro.core.quarantine import Quarantine
    camp = Campaign(CELLS, evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path, max_workers=4,
                    trial_timeout_s=60.0, max_retries=2)
    reports = camp.run()
    ref = sequential_reference()
    for key, rep in reports.items():
        assert rep.__dict__ == ref[key].__dict__
    assert "health" not in camp.last_stats
    assert "degraded_cells" not in camp.last_stats
    for spec in CELLS:
        d = json.loads((tmp_path / f"{spec.key()}.json").read_text())
        assert "health" not in d
    # the quarantine ledger holds only clean intent/complete pairs
    s = Quarantine(tmp_path).summary()
    assert s["intents"] == s["completions"] > 0
    assert s["strikes"] == {} and s["quarantined"] == []


def test_quarantine_opt_out_writes_no_ledger(tmp_path):
    camp = Campaign(CELLS[:1], evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path, quarantine=False)
    camp.run()
    assert not (tmp_path / "quarantine.jsonl").exists()


def test_hardening_requires_own_executor():
    from repro.core.executor import SweepExecutor
    with SweepExecutor(surface, max_workers=2) as ex:
        with pytest.raises(ValueError, match="executor"):
            Campaign(CELLS, evaluator=surface, executor=ex,
                     checkpoint_dir=None, trial_timeout_s=1.0)


def test_transient_faults_recovered_without_changing_decisions(tmp_path):
    """Every evaluation fails once with an environment fault; with
    retries the decisions are bit-identical to the fault-free run, the
    accounting shows the recovery, and nothing is marked degraded."""
    class FlakyOnce:
        def __init__(self):
            self.failed = set()
            self.lock = threading.Lock()

        def __call__(self, wl, rt):
            key = (wl.key(), json.dumps(rt.as_dict(), sort_keys=True))
            with self.lock:
                first = key not in self.failed
                self.failed.add(key)
            if first:
                raise OSError("environment hiccup")
            return surface(wl, rt)

    camp = Campaign(CELLS, evaluator=FlakyOnce(),
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path, max_workers=2,
                    max_retries=2)
    reports = camp.run()
    ref = sequential_reference()
    for key, rep in reports.items():
        assert tuning_fingerprint(rep) == tuning_fingerprint(ref[key])
    assert camp.last_stats["hardening"]["retries"] >= len(CELLS)
    assert camp.last_stats["degraded_cells"] == []
    for h in camp.last_stats["health"].values():
        assert set(h) == {"retries"}     # recovered: no failures left


def test_hang_bounded_and_degraded_reported(tmp_path):
    """A wedged evaluation is abandoned at the deadline, recorded as a
    timeout failure, and the cell completes degraded; untouched cells
    stay bit-identical.  Checkpoints and markdown both surface it."""
    import time as _time

    def hangy(wl, rt):
        if rt.microbatches == 2:
            _time.sleep(0.5)
        return surface(wl, rt)

    camp = Campaign(CELLS, evaluator=hangy,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path, max_workers=2,
                    trial_timeout_s=0.1)
    reports = camp.run()
    train_keys = sorted(c.key() for c in CELLS if "train" in c.shape)
    health = camp.last_stats["health"]
    for k in train_keys:
        assert health[k]["failures"]["timeout"] >= 1
        assert health[k]["degraded"]
    assert camp.last_stats["degraded_cells"] == train_keys
    assert camp.last_stats["hardening"]["timeouts"] >= 2
    ref = sequential_reference()
    for key in reports:
        if key not in train_keys:
            assert tuning_fingerprint(reports[key]) \
                == tuning_fingerprint(ref[key])
    d = json.loads((tmp_path / f"{train_keys[0]}.json").read_text())
    assert d["health"]["degraded"]
    md = report.campaign_markdown(reports, queue=camp.last_stats["queue"])
    assert "degraded cells" in md and "DEGRADED" in md
    assert "timeout" in md


def test_quarantined_config_skipped_fleet_wide(tmp_path):
    """A config at the strike threshold is never evaluated again — the
    propose path scores it as a crash in every cell of the campaign."""
    from repro.core.quarantine import Quarantine, config_key
    bf16 = baseline_factory(None).replace(compute_dtype="bfloat16")
    Quarantine(tmp_path).strike("a1", config_key(bf16), CELLS[0].key())
    counting = CountingSurface()
    camp = Campaign(CELLS[:2], evaluator=counting,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path, strike_threshold=1)
    reports = camp.run()
    evaluated = {json.dumps(c, sort_keys=True) for _, c in counting.calls}
    assert json.dumps(bf16.as_dict(), sort_keys=True) not in evaluated
    health = camp.last_stats["health"]
    for c in CELLS[:2]:
        assert health[c.key()]["quarantined"] >= 1
        assert health[c.key()]["degraded"]
    skipped = [e for e in reports[CELLS[0].key()].log
               if (e["result"].get("error") or "")
               .startswith("quarantined")]
    assert skipped and skipped[0]["result"]["crashed"]
