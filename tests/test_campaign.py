"""Campaign engine: interleaved cursors, checkpoint/resume, reporting.

All tests drive synthetic evaluators — no XLA compiles.  The
load-bearing invariants:

  * a campaign's per-cell reports are bit-identical to the sequential
    per-cell blocking driver (``run_tuning`` / ``run_sensitivity``),
    whatever strategy is selected;
  * an interrupted campaign resumes from ``results/campaign/``-style
    checkpoints without re-evaluating any completed (absorbed) trial,
    and converges to the same reports;
  * stale or corrupt checkpoints are discarded, never trusted;
    checkpoints from a different strategy are discarded with a warning,
    and PR-2-era (version-1) tree checkpoints are migrated in place.
"""
import dataclasses
import json
import threading

import pytest

from repro.core import report
from repro.core.campaign import (CHECKPOINT_VERSION, Campaign, CellSpec,
                                 enumerate_cells, parse_cells,
                                 tuning_fingerprint)
from repro.core.params import default_config
from repro.core.sensitivity import run_sensitivity
from repro.core.tree import run_tuning
from repro.core.trial import TrialResult, TrialRunner

CELLS = [CellSpec("smollm-135m", "train_4k"),
         CellSpec("smollm-135m", "prefill_32k"),
         CellSpec("glm4-9b", "train_4k"),
         CellSpec("xlstm-1.3b", "decode_32k")]


def baseline_factory(spec):
    return default_config(shard_strategy="fsdp_tp", attn_impl="pallas")


def surface(wl, rt):
    """Deterministic per-cell cost surface with one crash region."""
    if wl.arch == "glm4-9b" and rt.remat_policy == "full":
        return TrialResult(cost_s=float("inf"), crashed=True)
    c = 100.0 + 3.0 * len(wl.arch)
    if rt.compute_dtype == "bfloat16":
        c *= 0.7
    if rt.shard_strategy == "tp":
        c *= 0.9
    if rt.shard_strategy == "fsdp":
        c *= 1.1
    if rt.remat_policy == "none":
        c *= 1.2 if wl.arch == "glm4-9b" else 0.85
    if rt.microbatches == 2:
        c *= 0.97
    if rt.kv_cache_dtype == "int8":
        c *= 0.8
    if rt.attn_block_q == 256:
        c *= 0.92
    return TrialResult(cost_s=round(c, 6))


class CountingSurface:
    def __init__(self, fail_after=None):
        self.calls = []
        self.lock = threading.Lock()
        self.fail_after = fail_after

    def __call__(self, wl, rt):
        with self.lock:
            self.calls.append((wl.key(), rt.as_dict()))
            if self.fail_after is not None \
                    and len(self.calls) > self.fail_after:
                raise KeyboardInterrupt("simulated kill")
        return surface(wl, rt)


def sequential_reference():
    """The per-cell loop the campaign must reproduce bit for bit."""
    out = {}
    for spec in CELLS:
        runner = TrialRunner(spec.workload(), surface)
        out[spec.key()] = run_tuning(runner, baseline_factory(spec),
                                     threshold=0.05)
    return out


def test_campaign_matches_sequential_loop(tmp_path):
    camp = Campaign(CELLS, threshold=0.05, evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path, max_workers=4)
    reports = camp.run()
    ref = sequential_reference()
    assert list(reports) == [c.key() for c in CELLS]
    for key, rep in reports.items():
        # full bit-identity: log, n_trials, accepted, final_config
        assert rep.__dict__ == ref[key].__dict__
    assert camp.last_stats["evaluated_trials"] \
        == sum(r.n_trials for r in ref.values())


def test_campaign_without_checkpoints():
    camp = Campaign(CELLS, threshold=0.05, evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=None, max_workers=2)
    reports = camp.run()
    ref = sequential_reference()
    for key, rep in reports.items():
        assert tuning_fingerprint(rep) == tuning_fingerprint(ref[key])


def test_campaign_resume_replays_everything(tmp_path):
    camp = Campaign(CELLS, evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path)
    first = camp.run()
    counting = CountingSurface()
    camp2 = Campaign(CELLS, evaluator=counting,
                     baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path)
    second = camp2.run()
    assert counting.calls == []          # nothing re-paid
    assert camp2.last_stats["evaluated_trials"] == 0
    assert camp2.last_stats["replayed_trials"] \
        == camp.last_stats["trials"]
    for key in first:
        assert first[key].__dict__ == second[key].__dict__


def test_interrupted_campaign_resumes_without_repaying(tmp_path):
    """Kill mid-campaign, resume: no absorbed trial is re-evaluated and
    the final reports are identical to the uninterrupted run."""
    killer = CountingSurface(fail_after=9)
    camp = Campaign(CELLS, evaluator=killer,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path, max_workers=2)
    with pytest.raises(KeyboardInterrupt):
        camp.run()
    # what the checkpoints say is already absorbed
    absorbed = []
    for spec in CELLS:
        path = tmp_path / f"{spec.key()}.json"
        if path.exists():
            d = json.loads(path.read_text())
            absorbed += [(d["cell"], e["config"]) for e in d["log"]]
    assert absorbed                       # the kill landed mid-campaign
    resumer = CountingSurface()
    camp2 = Campaign(CELLS, evaluator=resumer,
                     baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path, max_workers=2)
    reports = camp2.run()
    # no completed trial was re-paid
    re_evaluated = {(k, json.dumps(c, sort_keys=True))
                    for k, c in resumer.calls}
    absorbed_set = {(k, json.dumps(c, sort_keys=True))
                    for k, c in absorbed}
    assert not re_evaluated & absorbed_set
    assert camp2.last_stats["replayed_trials"] == len(absorbed)
    ref = sequential_reference()
    for key, rep in reports.items():
        assert rep.__dict__ == ref[key].__dict__


def test_stale_checkpoint_discarded(tmp_path):
    """A checkpoint written under a different threshold (or tree) must
    not be replayed — the accept/reject decisions would be wrong."""
    Campaign(CELLS[:1], threshold=0.05, evaluator=surface,
             baseline_factory=baseline_factory,
             checkpoint_dir=tmp_path).run()
    counting = CountingSurface()
    camp = Campaign(CELLS[:1], threshold=0.10, evaluator=counting,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path)
    rep = camp.run()[CELLS[0].key()]
    assert camp.last_stats["replayed_trials"] == 0
    assert len(counting.calls) == rep.n_trials


def test_corrupt_checkpoint_discarded(tmp_path):
    spec = CELLS[0]
    (tmp_path / f"{spec.key()}.json").write_text("{not json")
    camp = Campaign([spec], evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path)
    rep = camp.run()[spec.key()]
    runner = TrialRunner(spec.workload(), surface)
    ref = run_tuning(runner, baseline_factory(spec), threshold=0.05)
    assert rep.__dict__ == ref.__dict__


def test_discard_checkpoints(tmp_path):
    camp = Campaign(CELLS[:2], evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path)
    camp.run()
    assert any(tmp_path.glob("*.json"))
    camp.discard_checkpoints()
    assert not list(tmp_path.glob("smollm*.json"))


def test_duplicate_cells_rejected():
    with pytest.raises(ValueError):
        Campaign([CELLS[0], CELLS[0]], evaluator=surface)


# -------------------------------------------------------- cell plumbing
def test_enumerate_cells_applicability():
    cells = enumerate_cells()
    keys = {(c.arch, c.shape) for c in cells}
    # long_500k only for sub-quadratic families (dryrun's skip rule)
    assert ("xlstm-1.3b", "long_500k") in keys
    assert ("zamba2-7b", "long_500k") in keys
    assert ("glm4-9b", "long_500k") not in keys
    assert ("glm4-9b", "train_4k") in keys
    assert all(not c.multi_pod for c in cells)
    both = enumerate_cells(archs=["smollm-135m"], shapes=["train_4k"],
                           meshes=(False, True))
    assert [c.multi_pod for c in both] == [False, True]


def test_parse_cells():
    cells = parse_cells("smollm-135m:train_4k, glm4-9b:train_4k:pod,"
                        "xlstm-1.3b:long_500k:multipod")
    assert cells[0] == CellSpec("smollm-135m", "train_4k")
    assert cells[1] == CellSpec("glm4-9b", "train_4k", False)
    assert cells[2] == CellSpec("xlstm-1.3b", "long_500k", True)
    with pytest.raises(ValueError):
        parse_cells("smollm-135m")                      # no shape
    with pytest.raises(KeyError):
        parse_cells("no-such-arch:train_4k")
    with pytest.raises(ValueError):
        parse_cells("glm4-9b:long_500k")                # not applicable
    with pytest.raises(ValueError):
        parse_cells("")


# --------------------------------------------------- strategy campaigns
def sens_fingerprint(rep):
    return json.dumps(dataclasses.asdict(rep), sort_keys=True,
                      default=str)


def test_sensitivity_campaign_matches_run_sensitivity(tmp_path):
    """Acceptance: SensitivityCursor through Campaign reproduces
    run_sensitivity's KnobImpact table exactly, per cell."""
    camp = Campaign(CELLS, strategy="sensitivity", evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path, max_workers=4)
    reports = camp.run()
    assert list(reports) == [c.key() for c in CELLS]
    for spec in CELLS:
        runner = TrialRunner(spec.workload(), surface)
        ref = run_sensitivity(runner, baseline_factory(spec))
        assert sens_fingerprint(reports[spec.key()]) \
            == sens_fingerprint(ref)
        assert reports[spec.key()].table() == ref.table()


def test_sensitivity_campaign_kill_and_resume(tmp_path):
    """Satellite: kill mid-campaign under the sensitivity strategy,
    resume — no absorbed trial re-paid, identical final reports."""
    killer = CountingSurface(fail_after=6)
    camp = Campaign(CELLS, strategy="sensitivity", evaluator=killer,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path, max_workers=2)
    with pytest.raises(KeyboardInterrupt):
        camp.run()
    absorbed = []
    for spec in CELLS:
        path = tmp_path / f"{spec.key()}.json"
        if path.exists():
            d = json.loads(path.read_text())
            assert d["strategy"] == "sensitivity"
            absorbed += [(d["cell"], e["config"]) for e in d["log"]]
    assert absorbed                       # the kill landed mid-campaign
    resumer = CountingSurface()
    camp2 = Campaign(CELLS, strategy="sensitivity", evaluator=resumer,
                     baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path, max_workers=2)
    reports = camp2.run()
    re_evaluated = {(k, json.dumps(c, sort_keys=True))
                    for k, c in resumer.calls}
    absorbed_set = {(k, json.dumps(c, sort_keys=True))
                    for k, c in absorbed}
    assert not re_evaluated & absorbed_set
    assert camp2.last_stats["replayed_trials"] == len(absorbed)
    for spec in CELLS:
        ref = run_sensitivity(TrialRunner(spec.workload(), surface),
                              baseline_factory(spec))
        assert sens_fingerprint(reports[spec.key()]) \
            == sens_fingerprint(ref)


def test_random_campaign_matches_direct_drive(tmp_path):
    from repro.core.strategy import drive, make_cursor
    camp = Campaign(CELLS, strategy="random",
                    strategy_options={"seed": 7, "budget": 5},
                    evaluator=surface, baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path)
    reports = camp.run()
    for spec in CELLS:
        ref = drive(make_cursor("random",
                                TrialRunner(spec.workload(), surface),
                                baseline_factory(spec),
                                options={"seed": 7, "budget": 5}))
        assert reports[spec.key()].__dict__ == ref.__dict__
    # resume replays everything
    counting = CountingSurface()
    camp2 = Campaign(CELLS, strategy="random",
                     strategy_options={"seed": 7, "budget": 5},
                     evaluator=counting,
                     baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path)
    camp2.run()
    assert counting.calls == []
    # different seed -> different signature -> silent fresh start
    camp3 = Campaign(CELLS[:1], strategy="random",
                     strategy_options={"seed": 8, "budget": 5},
                     evaluator=surface, baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path)
    camp3.run()
    assert camp3.last_stats["replayed_trials"] == 0


def test_stale_strategy_checkpoint_discarded_with_warning(tmp_path):
    """Satellite: a checkpoint written by a different strategy must be
    discarded with a warning, never crash resume."""
    Campaign(CELLS[:1], strategy="sensitivity", evaluator=surface,
             baseline_factory=baseline_factory,
             checkpoint_dir=tmp_path).run()
    counting = CountingSurface()
    with pytest.warns(UserWarning, match="stale checkpoint"):
        camp = Campaign(CELLS[:1], strategy="tree", evaluator=counting,
                        baseline_factory=baseline_factory,
                        checkpoint_dir=tmp_path)
        rep = camp.run()[CELLS[0].key()]
    assert camp.last_stats["replayed_trials"] == 0
    assert len(counting.calls) == rep.n_trials
    ref = run_tuning(TrialRunner(CELLS[0].workload(), surface),
                     baseline_factory(CELLS[0]), threshold=0.05)
    assert rep.__dict__ == ref.__dict__


def test_v1_tree_checkpoint_migration_shim(tmp_path):
    """PR-2-era checkpoints (version 1, no strategy field) must resume
    under the tree strategy without re-evaluating anything."""
    camp = Campaign(CELLS, evaluator=surface,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path)
    first = camp.run()
    for spec in CELLS:       # rewrite as PR-2-era layout
        path = tmp_path / f"{spec.key()}.json"
        d = json.loads(path.read_text())
        assert d["version"] == CHECKPOINT_VERSION
        d["version"] = 1
        del d["strategy"], d["strategy_version"]
        path.write_text(json.dumps(d))
    counting = CountingSurface()
    camp2 = Campaign(CELLS, evaluator=counting,
                     baseline_factory=baseline_factory,
                     checkpoint_dir=tmp_path)
    second = camp2.run()
    assert counting.calls == []          # nothing re-paid
    assert camp2.last_stats["evaluated_trials"] == 0
    for key in first:
        assert first[key].__dict__ == second[key].__dict__
    # ...but a v1 checkpoint under a non-tree strategy is stale
    for spec in CELLS:
        path = tmp_path / f"{spec.key()}.json"
        d = json.loads(path.read_text())
        d["version"] = 1
        d.pop("strategy", None), d.pop("strategy_version", None)
        path.write_text(json.dumps(d))
    with pytest.warns(UserWarning, match="stale checkpoint"):
        camp3 = Campaign(CELLS, strategy="random", evaluator=surface,
                         baseline_factory=baseline_factory,
                         checkpoint_dir=tmp_path)
        camp3.run()
    assert camp3.last_stats["replayed_trials"] == 0


def test_sensitivity_campaign_markdown(tmp_path):
    reports = Campaign(CELLS, strategy="sensitivity", evaluator=surface,
                       baseline_factory=baseline_factory,
                       checkpoint_dir=tmp_path).run()
    md = report.strategy_markdown(reports)
    assert "sensitivity impact per cell" in md
    assert "| knob (Spark analogue) |" in md
    cell_md = report.cell_markdown(next(iter(reports.values())))
    assert "### Sensitivity:" in cell_md and "mean abs %" in cell_md


def test_campaign_markdown(tmp_path):
    reports = Campaign(CELLS, evaluator=surface,
                       baseline_factory=baseline_factory,
                       checkpoint_dir=tmp_path).run()
    md = report.campaign_markdown(reports)
    assert "| arch |" in md
    assert "smollm-135m" in md and "xlstm-1.3b" in md
    assert f"cells tuned: {len(CELLS)}" in md
    assert "geometric-mean speedup" in md
