"""Per-kernel shape/dtype sweeps vs the ref.py pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.rmsnorm import ops as rms_ops, ref as rms_ref
from repro.kernels.ssm_scan import ops as ssm_ops, ref as ssm_ref


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,S,hd", [(1, 1, 128, 64), (2, 3, 256, 64),
                                      (1, 2, 512, 128), (2, 1, 128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("blocks", [(64, 64), (128, 128), (64, 128)])
def test_flash_attention_sweep(B, H, S, hd, dtype, blocks):
    bq, bk = blocks
    if S % bq or S % bk:
        pytest.skip("block does not divide")
    ks = jax.random.split(jax.random.PRNGKey(S + hd), 3)
    q, k, v = [jax.random.normal(kk, (B, S, H, hd)).astype(dtype)
               for kk in ks]
    out = fa_ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                 block_kv=bk)
    ref = fa_ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("shape", [(8, 64), (3, 37, 512), (2, 4, 16, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(sum(shape)))
    x = jax.random.normal(k1, shape).astype(dtype)
    s = (jax.random.normal(k2, shape[-1:]) * 0.1 + 1.0).astype(dtype)
    out = rms_ops.rmsnorm(x, s)
    ref = rms_ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 16), (2, 128, 4, 32, 16, 32), (1, 256, 1, 64, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_sweep(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S * H), 5)
    X = jax.random.normal(ks[0], (B, S, H, P)).astype(dtype)
    Bm = (jax.random.normal(ks[1], (B, S, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[2], (B, S, N)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    la = -dt * jnp.exp(jax.random.normal(ks[4], (H,)) * 0.2)[None, None]
    Y, h = ssm_ops.ssm_scan(X, Bm, Cm, dt, la, chunk=chunk)
    Yr, hr = ssm_ref.ssm_scan_ref(X, Bm, Cm, dt, la)
    t = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(Y, np.float32),
                               np.asarray(Yr, np.float32), **t)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), **t)


@pytest.mark.parametrize("B,H,Hkv,S,hd,length", [
    (1, 4, 4, 128, 64, 128), (2, 8, 2, 256, 64, 200),
    (1, 16, 16, 512, 128, 33)])
@pytest.mark.parametrize("kv_dtype", ["bfloat16", "int8"])
def test_flash_decode_sweep(B, H, Hkv, S, hd, length, kv_dtype):
    from repro.kernels.flash_decode import ops as fd, ref as fd_ref
    from repro.models.layers import quantize_kv
    ks = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    kc = jax.random.normal(ks[1], (B, S, Hkv, hd))
    vc = jax.random.normal(ks[2], (B, S, Hkv, hd))
    kq, ksc = quantize_kv(kc, kv_dtype)
    vq, vsc = quantize_kv(vc, kv_dtype)
    out = fd.flash_decode(q, kq, vq, length, ksc, vsc, block_kv=64)
    tr = lambda t: t.transpose(0, 2, 1, 3) if t is not None else None
    ref = fd_ref.decode_ref(tr(q), tr(kq), tr(vq), tr(ksc), tr(vsc),
                            jnp.array([length])).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = [jax.random.normal(kk, (2, 128, 2, 64)) for kk in ks]
    out = fa_ops.flash_attention(q, k, v, causal=False)
    ref = fa_ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=False).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------- ragged shapes (PR 7)
# The public wrappers fit any requested block to the largest divisor of
# the gridded dim (kernels/tiling.py) — sequence lengths that are NOT a
# multiple of the tile must stay correct, not assert-crash.

def test_fit_block():
    from repro.kernels.tiling import fit_block
    assert fit_block(128, 256) == 128      # divides: identity
    assert fit_block(512, 256) == 256      # clamp to n
    assert fit_block(128, 192) == 96       # largest divisor <= 128
    assert fit_block(128, 97) == 97        # prime: clamp wins
    assert fit_block(64, 97) == 1          # prime, block < n: degenerate
    assert fit_block(0, 64) == 1


@pytest.mark.parametrize("S", [192, 96, 300])
@pytest.mark.parametrize("blocks", [(128, 128), (256, 64)])
def test_flash_attention_ragged(S, blocks):
    bq, bk = blocks
    ks = jax.random.split(jax.random.PRNGKey(S), 3)
    q, k, v = [jax.random.normal(kk, (1, S, 2, 64)) for kk in ks]
    out = fa_ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                 block_kv=bk)
    ref = fa_ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("rows", [111, 5])
def test_rmsnorm_ragged_rows(rows):
    k1, k2 = jax.random.split(jax.random.PRNGKey(rows))
    x = jax.random.normal(k1, (rows, 64))
    s = jax.random.normal(k2, (64,)) * 0.1 + 1.0
    out = rms_ops.rmsnorm(x, s, block_rows=256)
    ref = rms_ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S,chunk", [(96, 64), (130, 32)])
def test_ssm_scan_ragged(S, chunk):
    B, H, P, N = 1, 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(S), 5)
    X = jax.random.normal(ks[0], (B, S, H, P))
    Bm = jax.random.normal(ks[1], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[2], (B, S, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    la = -dt * jnp.exp(jax.random.normal(ks[4], (H,)) * 0.2)[None, None]
    Y, h = ssm_ops.ssm_scan(X, Bm, Cm, dt, la, chunk=chunk)
    Yr, hr = ssm_ref.ssm_scan_ref(X, Bm, Cm, dt, la)
    np.testing.assert_allclose(np.asarray(Y), np.asarray(Yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=1e-4, rtol=1e-4)


def test_flash_decode_ragged_cache():
    B, H, Hkv, S, hd, length = 1, 4, 2, 192, 64, 150
    ks = jax.random.split(jax.random.PRNGKey(S), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    kc = jax.random.normal(ks[1], (B, S, Hkv, hd))
    vc = jax.random.normal(ks[2], (B, S, Hkv, hd))
    from repro.kernels.flash_decode import ops as fd, ref as fd_ref
    out = fd.flash_decode(q, kc, vc, length, block_kv=128)  # fit -> 96
    tr = lambda t: t.transpose(0, 2, 1, 3)
    ref = fd_ref.decode_ref(tr(q), tr(kc), tr(vc), None, None,
                            jnp.array([length])).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)
