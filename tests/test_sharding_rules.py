"""Property tests: sharding rules always emit valid PartitionSpecs
(axes exist in the mesh, no axis reused, divisibility respected)."""
import hypothesis as hp
import hypothesis.strategies as st
import numpy as np
import pytest

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.models.model import build_model
from repro.runtime.sharding import PARAM_LOGICAL, ShardingRules


def fake_mesh(shape=(4, 2), axes=("data", "model")):
    # abstract mesh: device objects only matter for NamedSharding, not
    # for spec construction — use the single real device replicated view
    devs = np.array(jax.devices() * int(np.prod(shape)))[
        :int(np.prod(shape))].reshape(shape)
    return Mesh(devs, axes)


MESH = fake_mesh()

logical_names = st.sampled_from(list(PARAM_LOGICAL))
dims = st.sampled_from([1, 2, 3, 4, 8, 9, 56, 64, 96, 100, 128])


@hp.settings(max_examples=80, deadline=None)
@hp.given(strategy=st.sampled_from(["dp", "fsdp", "tp", "fsdp_tp"]),
          logical=st.lists(logical_names, min_size=1, max_size=4),
          shape=st.lists(dims, min_size=4, max_size=4))
def test_param_spec_always_valid(strategy, logical, shape):
    shape = shape[:len(logical)]
    rules = ShardingRules(mesh=MESH, strategy=strategy)
    spec = rules.param_spec(tuple(logical), tuple(shape))
    assert isinstance(spec, P)
    used = []
    for i, ax in enumerate(spec):
        axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
        for a in axes:
            assert a in MESH.shape, f"unknown axis {a}"
            assert a not in used, f"axis {a} reused in {spec}"
            used.append(a)
        # divisibility
        n = int(np.prod([MESH.shape[a] for a in axes])) if axes else 1
        assert shape[i] % n == 0, f"dim {shape[i]} not divisible by {n}"


@hp.settings(max_examples=40, deadline=None)
@hp.given(strategy=st.sampled_from(["dp", "fsdp", "tp", "fsdp_tp"]),
          batch=st.sampled_from([1, 2, 4, 8, 9, 64]))
def test_act_spec_always_valid(strategy, batch):
    rules = ShardingRules(mesh=MESH, strategy=strategy)
    spec = rules.act_spec(("batch", None, "heads"), (batch, 16, 8))
    assert isinstance(spec, P)
    for i, ax in enumerate(spec):
        axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
        n = int(np.prod([MESH.shape[a] for a in axes])) if axes else 1
        assert (batch, 16, 8)[i] % n == 0


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("strategy", ["fsdp_tp", "tp"])
def test_full_arch_param_specs_valid_on_production_mesh(arch, strategy):
    """Every FULL config's param tree maps to valid specs on 16x16."""
    mesh = fake_mesh((16, 16), ("data", "model"))
    cfg = get_config(arch)
    model = build_model(cfg)
    rules = ShardingRules(mesh=mesh, strategy=strategy,
                          fsdp_axes=cfg.fsdp_axes)
    shapes = model.param_shapes()
    logical = model.logical()

    def check(lg, sd):
        spec = rules.param_spec(lg, sd.shape)
        used = set()
        for i, ax in enumerate(spec):
            axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
            for a in axes:
                assert a not in used
                used.add(a)
            n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
            assert sd.shape[i] % n == 0, (arch, lg, sd.shape, spec)
        return spec

    jax.tree.map(check, logical, shapes,
                 is_leaf=lambda x: isinstance(x, tuple) and all(
                     isinstance(e, (str, type(None))) for e in x))


def test_multipod_pod_axis_in_batch():
    mesh = fake_mesh((2, 4, 2), ("pod", "data", "model"))
    rules = ShardingRules(mesh=mesh, strategy="fsdp_tp",
                          fsdp_axes=("data", "pod"))
    spec = rules.act_spec(("batch", None), (16, 8))
    assert spec[0] == ("pod", "data")
    # fsdp over (data, pod) on a param embed dim
    pspec = rules.param_spec(("embed", "mlp"), (64, 32))
    assert "data" in (pspec[0] if isinstance(pspec[0], tuple) else (pspec[0],))
