"""Property-style tests: sharding rules always emit valid PartitionSpecs
(axes exist in the mesh, no axis reused, divisibility respected).

Formerly hypothesis-based; rewritten as seeded parametrized sampling so
the suite has no hard dependency on `hypothesis`."""
import numpy as np
import pytest

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.models.model import build_model
from repro.runtime.sharding import PARAM_LOGICAL, ShardingRules


def fake_mesh(shape=(4, 2), axes=("data", "model")):
    # abstract mesh: device objects only matter for NamedSharding, not
    # for spec construction — use the single real device replicated view
    devs = np.array(jax.devices() * int(np.prod(shape)))[
        :int(np.prod(shape))].reshape(shape)
    return Mesh(devs, axes)


MESH = fake_mesh()

STRATEGIES = ["dp", "fsdp", "tp", "fsdp_tp"]
DIMS = [1, 2, 3, 4, 8, 9, 56, 64, 96, 100, 128]


def _param_cases(n=80):
    """Seeded analogue of the old hypothesis strategy."""
    rng = np.random.RandomState(0)
    names = list(PARAM_LOGICAL)
    cases = []
    for _ in range(n):
        strategy = STRATEGIES[rng.randint(len(STRATEGIES))]
        logical = tuple(names[rng.randint(len(names))]
                        for _ in range(rng.randint(1, 5)))
        shape = tuple(DIMS[rng.randint(len(DIMS))]
                      for _ in range(len(logical)))
        cases.append((strategy, logical, shape))
    return cases


@pytest.mark.parametrize("strategy,logical,shape", _param_cases())
def test_param_spec_always_valid(strategy, logical, shape):
    rules = ShardingRules(mesh=MESH, strategy=strategy)
    spec = rules.param_spec(tuple(logical), tuple(shape))
    assert isinstance(spec, P)
    used = []
    for i, ax in enumerate(spec):
        axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
        for a in axes:
            assert a in MESH.shape, f"unknown axis {a}"
            assert a not in used, f"axis {a} reused in {spec}"
            used.append(a)
        # divisibility
        n = int(np.prod([MESH.shape[a] for a in axes])) if axes else 1
        assert shape[i] % n == 0, f"dim {shape[i]} not divisible by {n}"


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("batch", [1, 2, 4, 8, 9, 64])
def test_act_spec_always_valid(strategy, batch):
    rules = ShardingRules(mesh=MESH, strategy=strategy)
    spec = rules.act_spec(("batch", None, "heads"), (batch, 16, 8))
    assert isinstance(spec, P)
    for i, ax in enumerate(spec):
        axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
        n = int(np.prod([MESH.shape[a] for a in axes])) if axes else 1
        assert (batch, 16, 8)[i] % n == 0


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("strategy", ["fsdp_tp", "tp"])
def test_full_arch_param_specs_valid_on_production_mesh(arch, strategy):
    """Every FULL config's param tree maps to valid specs on 16x16."""
    mesh = fake_mesh((16, 16), ("data", "model"))
    cfg = get_config(arch)
    model = build_model(cfg)
    rules = ShardingRules(mesh=mesh, strategy=strategy,
                          fsdp_axes=cfg.fsdp_axes)
    shapes = model.param_shapes()
    logical = model.logical()

    def check(lg, sd):
        spec = rules.param_spec(lg, sd.shape)
        used = set()
        for i, ax in enumerate(spec):
            axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
            for a in axes:
                assert a not in used
                used.add(a)
            n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
            assert sd.shape[i] % n == 0, (arch, lg, sd.shape, spec)
        return spec

    jax.tree.map(check, logical, shapes,
                 is_leaf=lambda x: isinstance(x, tuple) and all(
                     isinstance(e, (str, type(None))) for e in x))


def test_multipod_pod_axis_in_batch():
    mesh = fake_mesh((2, 4, 2), ("pod", "data", "model"))
    rules = ShardingRules(mesh=mesh, strategy="fsdp_tp",
                          fsdp_axes=("data", "pod"))
    spec = rules.act_spec(("batch", None), (16, 8))
    assert spec[0] == ("pod", "data")
    # fsdp over (data, pod) on a param embed dim
    pspec = rules.param_spec(("embed", "mlp"), (64, 32))
    assert "data" in (pspec[0] if isinstance(pspec[0], tuple) else (pspec[0],))
