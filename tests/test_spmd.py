"""SPMD integration tests (run in subprocesses with 8 fake host devices
so the main pytest process keeps seeing 1 device, per the dry-run rule).

Covers: dp == tp == fsdp numerical equivalence of a real train step,
explicit-collective gradsync == auto path, and MoE expert-parallel
all-to-all path == dense reference.
"""
import os
import subprocess
import sys
import textwrap

import pytest


def run_sub(code: str, timeout=570) -> str:
    prelude = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
    """)
    out = subprocess.run([sys.executable, "-c", prelude + textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root",
                              # keep children off TPU autodetection (no
                              # hardware attached; blocks for minutes)
                              "JAX_PLATFORMS": os.environ.get(
                                  "JAX_PLATFORMS", "cpu")},
                         cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_strategies_numerically_equivalent():
    """The shuffle-manager knob changes transport, not math: one train
    step under dp / tp / fsdp / fsdp_tp produces the same loss + params."""
    out = run_sub("""
        from repro.configs import get_reduced
        from repro.configs.base import ShapeConfig
        from repro.core.params import default_config
        from repro.launch.mesh import make_mesh
        from repro.models.model import build_model, synth_inputs
        from repro.optim.optimizers import constant_schedule, make_optimizer
        from repro.runtime.stepfn import build_train_step

        mesh = make_mesh((4, 2), ("data", "model"))
        cfg = get_reduced("smollm-135m")
        shape = ShapeConfig("t", 64, 8, "train")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = make_optimizer("adamw", constant_schedule(1e-3))
        batch = synth_inputs(cfg, shape, default_config(),
                             jax.random.PRNGKey(1))
        results = {}
        for strat in ("dp", "tp", "fsdp", "fsdp_tp"):
            rt = default_config(shard_strategy=strat, donate_buffers=False)
            b = build_train_step(cfg, shape, rt, mesh, opt)
            with mesh:
                p2, s2, met = b.fn(params, opt.init(params), batch)
            results[strat] = (float(met["loss"]),
                              float(jnp.mean(jnp.abs(p2["final_norm"]))))
            print(strat, results[strat], "explicit:",
                  b.notes["explicit_comm"])
        base = results["dp"]
        for k, v in results.items():
            assert abs(v[0] - base[0]) < 1e-4, (k, v, base)
            assert abs(v[1] - base[1]) < 1e-4, (k, v, base)
        print("EQUIVALENT")
    """)
    assert "EQUIVALENT" in out


@pytest.mark.slow
def test_gradsync_comm_dtype_and_fusion_close_to_f32():
    """bf16/fused gradient collectives change bytes, not correctness."""
    out = run_sub("""
        from repro.configs import get_reduced
        from repro.configs.base import ShapeConfig
        from repro.core.params import default_config
        from repro.launch.mesh import make_mesh
        from repro.models.model import build_model, synth_inputs
        from repro.optim.optimizers import constant_schedule, make_optimizer
        from repro.runtime.stepfn import build_train_step

        mesh = make_mesh((8,), ("data",))
        cfg = get_reduced("smollm-135m")
        shape = ShapeConfig("t", 64, 8, "train")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = make_optimizer("adamw", constant_schedule(1e-3))
        batch = synth_inputs(cfg, shape, default_config(),
                             jax.random.PRNGKey(1))
        losses = {}
        for name, kw in {
            "f32": dict(),
            "bf16": dict(grad_comm_dtype="bfloat16"),
            "fused": dict(fuse_grad_collectives=True),
            "fsdp_bf16": dict(grad_comm_dtype="bfloat16"),
        }.items():
            rt = default_config(shard_strategy="fsdp"
                                if name.startswith("fsdp") else "dp",
                                donate_buffers=False, **kw)
            b = build_train_step(cfg, shape, rt, mesh, opt)
            assert b.notes["explicit_comm"], name
            with mesh:
                p2, s2, met = b.fn(params, opt.init(params), batch)
            losses[name] = float(met["loss"])
            print(name, losses[name])
        for k, v in losses.items():
            assert abs(v - losses["f32"]) < 5e-3, (k, v)
        print("GRADSYNC_OK")
    """)
    assert "GRADSYNC_OK" in out


@pytest.mark.slow
def test_int8_ef_gradient_compression_converges():
    """int8+error-feedback all-reduce: loss still decreases ~like f32."""
    out = run_sub("""
        from repro.configs import get_reduced
        from repro.configs.base import ShapeConfig
        from repro.core.params import default_config
        from repro.launch.mesh import make_mesh
        from repro.models.model import build_model, synth_inputs
        from repro.optim.optimizers import constant_schedule, make_optimizer
        from repro.runtime.stepfn import build_train_step

        mesh = make_mesh((8,), ("data",))
        cfg = get_reduced("smollm-135m")
        shape = ShapeConfig("t", 64, 8, "train")
        model = build_model(cfg)
        params0 = model.init(jax.random.PRNGKey(0))
        opt = make_optimizer("adamw", constant_schedule(1e-3))
        batch = synth_inputs(cfg, shape, default_config(),
                             jax.random.PRNGKey(1))
        final = {}
        for gcd in ("float32", "int8_ef"):
            rt = default_config(shard_strategy="dp", grad_comm_dtype=gcd,
                                fuse_grad_collectives=True,
                                donate_buffers=False)
            b = build_train_step(cfg, shape, rt, mesh, opt)
            params = params0
            st = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                              b.args[1])
            with mesh:
                for _ in range(4):
                    params, st, met = b.fn(params, st, batch)
            final[gcd] = float(met["loss"])
            print(gcd, final[gcd])
        assert final["int8_ef"] < 6.25          # decreased from ~6.25
        assert abs(final["int8_ef"] - final["float32"]) < 0.1
        print("EF_OK")
    """)
    assert "EF_OK" in out


@pytest.mark.slow
def test_moe_ep_alltoall_matches_dense():
    """Expert-parallel dispatch/combine == dense reference (generous
    capacity so nothing drops)."""
    out = run_sub("""
        from repro.configs import get_reduced
        from repro.core.params import default_config
        from repro.launch.mesh import make_mesh
        from repro.models import moe
        from repro.models.layers import init_params
        from repro.runtime.sharding import ShardingRules

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = get_reduced("olmoe-1b-7b").replace(capacity_factor=8.0)
        rt = default_config(compute_dtype="float32",
                            comm_codec="float32")  # uncompressed wire
        spec = moe.moe_spec(cfg)
        params = init_params(spec, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
        rules = ShardingRules(mesh=mesh, strategy="fsdp_tp")
        with mesh:
            y_ep, aux_ep = jax.jit(
                lambda p, xx: moe.moe_mlp(p, xx, cfg, rt, rules))(params, x)
        y_dense, aux_dense = moe._dense_moe(params, x, cfg, rt)
        err = float(jnp.max(jnp.abs(y_ep - y_dense)))
        print("err", err, "aux", float(aux_ep), float(aux_dense))
        assert err < 1e-4, err
        # EP aux is the mean of per-shard load-balance estimators
        # (standard Switch-style per-device aux) — close to, but not
        # identical with, the global-batch estimator
        assert abs(float(aux_ep) - float(aux_dense)) < 0.1
        print("MOE_OK")
    """)
    assert "MOE_OK" in out


@pytest.mark.slow
def test_moe_ep_gather_decode_path():
    out = run_sub("""
        from repro.configs import get_reduced
        from repro.core.params import default_config
        from repro.launch.mesh import make_mesh
        from repro.models import moe
        from repro.models.layers import init_params
        from repro.runtime.sharding import ShardingRules

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = get_reduced("olmoe-1b-7b").replace(capacity_factor=8.0)
        rt = default_config(compute_dtype="float32",
                            comm_codec="float32")  # uncompressed wire
        params = init_params(moe.moe_spec(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 1, cfg.d_model))
        rules = ShardingRules(mesh=mesh, strategy="tp")
        with mesh:
            y_ep, _ = jax.jit(
                lambda p, xx: moe.moe_mlp(p, xx, cfg, rt, rules))(params, x)
        y_dense, _ = moe._dense_moe(params, x, cfg, rt)
        err = float(jnp.max(jnp.abs(y_ep - y_dense)))
        print("err", err)
        assert err < 1e-4, err
        print("GATHER_OK")
    """)
    assert "GATHER_OK" in out
