"""Parallel sweep executor: ordering, dedup, fault isolation, and
equivalence of parallel vs sequential sweeps (log + budget accounting)."""
import threading
import time

import pytest

from repro.core.executor import SweepExecutor, run_trials
from repro.core.params import default_config
from repro.core.sensitivity import run_sensitivity
from repro.core.tree import MAX_TRIALS, run_tuning
from repro.core.trial import TrialResult, TrialRunner, Workload

WL = Workload("smollm-135m", "train_4k")


class CountingEvaluator:
    """Deterministic cost surface + thread-safe call accounting."""

    def __init__(self, delay=0.0, crash_on=None, raise_on=None):
        self.calls = []
        self.lock = threading.Lock()
        self.delay = delay
        self.crash_on = crash_on or {}
        self.raise_on = raise_on or {}

    def __call__(self, wl, rt):
        with self.lock:
            self.calls.append(rt)
        if self.delay:
            time.sleep(self.delay)
        for k, v in self.raise_on.items():
            if getattr(rt, k) == v:
                raise RuntimeError("boom")
        for k, v in self.crash_on.items():
            if getattr(rt, k) == v:
                return TrialResult(cost_s=float("inf"), crashed=True)
        cost = 100.0 + 7.0 * rt.microbatches \
            - (30.0 if rt.compute_dtype == "bfloat16" else 0.0)
        return TrialResult(cost_s=cost)


def test_map_preserves_order_and_values():
    ev = CountingEvaluator(delay=0.01)
    base = default_config()
    cfgs = [base.replace(microbatches=m) for m in (1, 2, 4)] \
        + [base.replace(compute_dtype="bfloat16")]
    with SweepExecutor(ev, max_workers=4) as ex:
        results = ex.map(WL, cfgs)
    assert [r.cost_s for r in results] == [107.0, 114.0, 128.0, 77.0]


def test_inflight_dedup_single_evaluation():
    ev = CountingEvaluator(delay=0.05)
    cfg = default_config()
    with SweepExecutor(ev, max_workers=4) as ex:
        futs = [ex.submit(WL, cfg) for _ in range(6)]
        results = [f.result() for f in futs]
    assert len(ev.calls) == 1
    assert ex.stats()["deduped"] == 5
    assert all(r.cost_s == 107.0 for r in results)


def test_evaluator_exception_becomes_crashed_result():
    ev = CountingEvaluator(raise_on={"microbatches": 2})
    base = default_config()
    with SweepExecutor(ev, max_workers=2) as ex:
        good, bad = ex.map(WL, [base, base.replace(microbatches=2)])
    assert not good.crashed
    assert bad.crashed and bad.cost_s == float("inf")
    assert "boom" in bad.error


def test_prefetch_warms_without_blocking():
    ev = CountingEvaluator(delay=0.05)
    base = default_config()
    with SweepExecutor(ev, max_workers=2) as ex:
        t0 = time.time()
        ex.prefetch(WL, [base.replace(microbatches=m) for m in (1, 2, 4)])
        assert time.time() - t0 < 0.04      # fire-and-forget
        # a later submit of a prefetched config dedups onto its future
        res = ex.submit(WL, base.replace(microbatches=2)).result()
    assert res.cost_s == 114.0
    assert len(ev.calls) == 3


def test_run_trials_returns_log_indices():
    """Annotation contract: each (index, result) pair points at the
    exact runner.log entry the candidate was recorded at, on both the
    sequential and the executor path."""
    base = default_config()
    cands = [(base, "a", None), (base.replace(microbatches=2), "b", None)]
    runner = TrialRunner(WL, CountingEvaluator())
    runner.run(base, "warmup")               # offset the log
    pairs = run_trials(runner, cands)
    assert [i for i, _ in pairs] == [1, 2]
    ev = CountingEvaluator()
    par_runner = TrialRunner(WL, ev)
    with SweepExecutor(ev, max_workers=2) as ex:
        par_pairs = run_trials(par_runner, cands, ex)
    assert [i for i, _ in par_pairs] == [0, 1]
    for (i, res), (_, name, _d) in zip(par_pairs, cands):
        assert par_runner.log[i].name == name
        assert par_runner.log[i].result["cost_s"] == res.cost_s


def test_run_trials_rejects_foreign_executor():
    runner = TrialRunner(WL, CountingEvaluator())
    with SweepExecutor(CountingEvaluator()) as ex:
        with pytest.raises(ValueError):
            run_trials(runner, [(default_config(), "x", None)], ex)


@pytest.mark.parametrize("crash", [{}, {"remat_policy": "full"}])
def test_sensitivity_parallel_equals_sequential(crash):
    base = default_config(shard_strategy="fsdp_tp")
    seq_runner = TrialRunner(WL, CountingEvaluator(crash_on=crash))
    seq = run_sensitivity(seq_runner, base)
    par_ev = CountingEvaluator(crash_on=crash)
    with SweepExecutor(par_ev, max_workers=4) as ex:
        par_runner = TrialRunner(WL, par_ev)
        par = run_sensitivity(par_runner, base, executor=ex)
    assert par.n_trials == seq.n_trials
    assert par.baseline_cost == seq.baseline_cost
    for a, b in zip(seq.impacts, par.impacts):
        assert (a.knob, a.values, a.crashes) == (b.knob, b.values, b.crashes)
        assert a.deviations_pct == pytest.approx(b.deviations_pct,
                                                 nan_ok=True)
    # identical log layout (names + notes), deterministic order
    assert [(e.name, e.note) for e in seq_runner.log] \
        == [(e.name, e.note) for e in par_runner.log]


@pytest.mark.parametrize("crash", [{}, {"remat_policy": "full"}])
def test_tree_parallel_equals_sequential(crash):
    base = default_config(shard_strategy="fsdp_tp")
    seq_runner = TrialRunner(WL, CountingEvaluator(crash_on=crash))
    seq = run_tuning(seq_runner, base, threshold=0.05)
    par_ev = CountingEvaluator(crash_on=crash)
    with SweepExecutor(par_ev, max_workers=4) as ex:
        par_runner = TrialRunner(WL, par_ev)
        par = run_tuning(par_runner, base, threshold=0.05, executor=ex)
    assert par.n_trials == seq.n_trials <= MAX_TRIALS
    assert par.final_cost == seq.final_cost
    assert par.final_config == seq.final_config
    assert par.accepted == seq.accepted
    assert [(e["name"], e["accepted"]) for e in seq.log] \
        == [(e["name"], e["accepted"]) for e in par.log]


# ----------------------------------------------------- hardening layer
from repro.core.quarantine import Quarantine, config_key
from repro.core.trial import (FAILURE_DETERMINISTIC, FAILURE_TIMEOUT,
                              FAILURE_TRANSIENT, FAILURE_WORKER_DEATH)


class FlakyEvaluator(CountingEvaluator):
    """Raises OSError (the transient class) for the first ``fails``
    calls per config, then defers to the deterministic surface."""

    def __init__(self, fails=1, **kw):
        super().__init__(**kw)
        self.fails = fails
        self.failed = {}

    def __call__(self, wl, rt):
        with self.lock:
            blob = tuple(sorted(rt.as_dict().items()))
            n = self.failed.get(blob, 0)
            if n < self.fails:
                self.failed[blob] = n + 1
                self.calls.append(rt)
                raise OSError(f"flaky ({n + 1}/{self.fails})")
        return super().__call__(wl, rt)


def test_deadline_times_out_wedged_trial():
    ev = CountingEvaluator(delay=0.5)
    with SweepExecutor(ev, max_workers=2, trial_timeout_s=0.05) as ex:
        res = ex.submit(WL, default_config()).result()
    assert res.crashed and res.cost_s == float("inf")
    assert res.failure == FAILURE_TIMEOUT
    assert "deadline" in res.error
    assert ex.stats()["timeouts"] == 1


def test_deadline_leaves_fast_trials_untouched():
    ev = CountingEvaluator()
    with SweepExecutor(ev, max_workers=2, trial_timeout_s=5.0) as ex:
        res = ex.submit(WL, default_config()).result()
    assert not res.crashed and res.cost_s == 107.0
    assert ex.stats()["timeouts"] == 0


def test_zombie_thread_reaped_after_it_unwedges():
    def ev(wl, rt):
        if rt.microbatches == 2:
            time.sleep(0.2)
        return TrialResult(cost_s=1.0)

    with SweepExecutor(ev, max_workers=2, trial_timeout_s=0.05) as ex:
        slow = ex.submit(WL, default_config().replace(microbatches=2))
        assert slow.result().failure == FAILURE_TIMEOUT
        assert ex.stats()["zombies"] == 1   # abandoned, not joined
        time.sleep(0.3)                     # the wedged eval finishes
        fast = ex.submit(WL, default_config()).result()  # reaps on submit
        assert not fast.crashed
        assert ex.stats()["zombies"] == 0


def test_transient_failure_retried_to_success():
    ev = FlakyEvaluator(fails=1)
    with SweepExecutor(ev, max_workers=2, max_retries=2,
                       retry_backoff_s=0.001) as ex:
        res = ex.submit(WL, default_config()).result()
    assert not res.crashed and res.cost_s == 107.0
    assert res.retries == 1                 # accounting travels with it
    assert ex.stats()["retries"] == 1
    assert len(ev.calls) == 2


def test_retry_exhaustion_keeps_transient_classification():
    ev = FlakyEvaluator(fails=99)
    with SweepExecutor(ev, max_workers=2, max_retries=2,
                       retry_backoff_s=0.001) as ex:
        res = ex.submit(WL, default_config()).result()
    assert res.crashed and res.failure == FAILURE_TRANSIENT
    assert res.retries == 2
    assert len(ev.calls) == 3               # 1 attempt + 2 retries


def test_deterministic_failure_never_retried():
    ev = CountingEvaluator(raise_on={"microbatches": 2})
    cfg = default_config().replace(microbatches=2)
    with SweepExecutor(ev, max_workers=2, max_retries=3) as ex:
        res = ex.submit(WL, cfg).result()
    assert res.crashed and res.failure == FAILURE_DETERMINISTIC
    assert res.retries == 0 and len(ev.calls) == 1
    assert ex.stats()["retries"] == 0


def test_fresh_submit_after_crash_reevaluates():
    """A finished (crashed) future leaves the in-flight table, so a
    later submit re-evaluates instead of dedup-ing onto the crash."""
    ev = FlakyEvaluator(fails=1)
    with SweepExecutor(ev, max_workers=2) as ex:    # no retries
        bad = ex.submit(WL, default_config()).result()
        good = ex.submit(WL, default_config()).result()
    assert bad.crashed and bad.failure == FAILURE_TRANSIENT
    assert not good.crashed and good.cost_s == 107.0


def test_quarantine_brackets_every_evaluation(tmp_path):
    q = Quarantine(tmp_path, worker="t0")
    ev = CountingEvaluator()
    with SweepExecutor(ev, max_workers=2, quarantine=q) as ex:
        ex.submit(WL, default_config()).result()
    recs = q.records()
    assert [r["type"] for r in recs] == ["intent", "complete"]
    assert recs[0]["key"] == config_key(default_config())
    assert recs[0]["cell"] == WL.key()
    assert recs[1]["crashed"] is False


def test_quarantined_config_skipped_and_scored_as_crash(tmp_path):
    q = Quarantine(tmp_path, strike_threshold=1)
    cfg = default_config()
    q.strike("att-1", config_key(cfg), WL.key())
    ev = CountingEvaluator()
    with SweepExecutor(ev, max_workers=2, quarantine=q) as ex:
        res = ex.submit(WL, cfg).result()
        other = ex.submit(WL, cfg.replace(microbatches=2)).result()
    assert res.crashed and res.failure == FAILURE_WORKER_DEATH
    assert res.error.startswith("quarantined")
    assert not other.crashed                # only the struck config
    assert ev.calls == [cfg.replace(microbatches=2)]
    assert ex.stats()["quarantined"] == 1


def test_stats_counters_exact_under_concurrent_stress():
    """Regression (counter thread-safety): every accounting counter is
    incremented under ``self._lock`` (``SweepExecutor._count``), so
    hammering submit from many client threads must yield *exact*
    totals — an approximately-right count is a lost-increment race."""
    n_threads, per_thread, n_cfgs = 8, 25, 8
    ev = FlakyEvaluator(fails=1)     # first eval per config: transient
    base = default_config()
    cfgs = [base.replace(microbatches=m) for m in range(1, n_cfgs + 1)]
    with SweepExecutor(ev, max_workers=8, max_retries=1,
                       retry_backoff_s=0.0) as ex:
        barrier = threading.Barrier(n_threads)

        def hammer(t):
            barrier.wait()               # maximize submit contention
            for i in range(per_thread):
                ex.submit(WL, cfgs[(t + i) % n_cfgs]).result()

        threads = [threading.Thread(target=hammer, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        stats = ex.stats()
    assert stats["submitted"] == n_threads * per_thread
    assert stats["submitted"] == stats["evals"] + stats["deduped"]
    # each distinct config's *first* evaluation pays exactly one
    # transient retry; later evaluations of it succeed outright
    assert stats["retries"] == n_cfgs
    # the evaluator saw one call per evaluation plus one per retry
    assert len(ev.calls) == stats["evals"] + stats["retries"]
    assert stats["timeouts"] == 0 and stats["quarantined"] == 0


def test_timeout_strikes_toward_quarantine(tmp_path):
    """A hang is as poisonous as a kill, just slower: K timeouts of one
    config quarantine it, so the hang is paid at most K times."""
    q = Quarantine(tmp_path, strike_threshold=1)
    ev = CountingEvaluator(delay=0.3)
    cfg = default_config()
    with SweepExecutor(ev, max_workers=2, trial_timeout_s=0.05,
                       quarantine=q) as ex:
        first = ex.submit(WL, cfg).result()
        second = ex.submit(WL, cfg).result()
    assert first.failure == FAILURE_TIMEOUT
    assert second.error.startswith("quarantined")
    assert len(ev.calls) == 1               # evaluated exactly K=1 times
