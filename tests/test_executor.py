"""Parallel sweep executor: ordering, dedup, fault isolation, and
equivalence of parallel vs sequential sweeps (log + budget accounting)."""
import threading
import time

import pytest

from repro.core.executor import SweepExecutor, run_trials
from repro.core.params import default_config
from repro.core.sensitivity import run_sensitivity
from repro.core.tree import MAX_TRIALS, run_tuning
from repro.core.trial import TrialResult, TrialRunner, Workload

WL = Workload("smollm-135m", "train_4k")


class CountingEvaluator:
    """Deterministic cost surface + thread-safe call accounting."""

    def __init__(self, delay=0.0, crash_on=None, raise_on=None):
        self.calls = []
        self.lock = threading.Lock()
        self.delay = delay
        self.crash_on = crash_on or {}
        self.raise_on = raise_on or {}

    def __call__(self, wl, rt):
        with self.lock:
            self.calls.append(rt)
        if self.delay:
            time.sleep(self.delay)
        for k, v in self.raise_on.items():
            if getattr(rt, k) == v:
                raise RuntimeError("boom")
        for k, v in self.crash_on.items():
            if getattr(rt, k) == v:
                return TrialResult(cost_s=float("inf"), crashed=True)
        cost = 100.0 + 7.0 * rt.microbatches \
            - (30.0 if rt.compute_dtype == "bfloat16" else 0.0)
        return TrialResult(cost_s=cost)


def test_map_preserves_order_and_values():
    ev = CountingEvaluator(delay=0.01)
    base = default_config()
    cfgs = [base.replace(microbatches=m) for m in (1, 2, 4)] \
        + [base.replace(compute_dtype="bfloat16")]
    with SweepExecutor(ev, max_workers=4) as ex:
        results = ex.map(WL, cfgs)
    assert [r.cost_s for r in results] == [107.0, 114.0, 128.0, 77.0]


def test_inflight_dedup_single_evaluation():
    ev = CountingEvaluator(delay=0.05)
    cfg = default_config()
    with SweepExecutor(ev, max_workers=4) as ex:
        futs = [ex.submit(WL, cfg) for _ in range(6)]
        results = [f.result() for f in futs]
    assert len(ev.calls) == 1
    assert ex.stats()["deduped"] == 5
    assert all(r.cost_s == 107.0 for r in results)


def test_evaluator_exception_becomes_crashed_result():
    ev = CountingEvaluator(raise_on={"microbatches": 2})
    base = default_config()
    with SweepExecutor(ev, max_workers=2) as ex:
        good, bad = ex.map(WL, [base, base.replace(microbatches=2)])
    assert not good.crashed
    assert bad.crashed and bad.cost_s == float("inf")
    assert "boom" in bad.error


def test_prefetch_warms_without_blocking():
    ev = CountingEvaluator(delay=0.05)
    base = default_config()
    with SweepExecutor(ev, max_workers=2) as ex:
        t0 = time.time()
        ex.prefetch(WL, [base.replace(microbatches=m) for m in (1, 2, 4)])
        assert time.time() - t0 < 0.04      # fire-and-forget
        # a later submit of a prefetched config dedups onto its future
        res = ex.submit(WL, base.replace(microbatches=2)).result()
    assert res.cost_s == 114.0
    assert len(ev.calls) == 3


def test_run_trials_returns_log_indices():
    """Annotation contract: each (index, result) pair points at the
    exact runner.log entry the candidate was recorded at, on both the
    sequential and the executor path."""
    base = default_config()
    cands = [(base, "a", None), (base.replace(microbatches=2), "b", None)]
    runner = TrialRunner(WL, CountingEvaluator())
    runner.run(base, "warmup")               # offset the log
    pairs = run_trials(runner, cands)
    assert [i for i, _ in pairs] == [1, 2]
    ev = CountingEvaluator()
    par_runner = TrialRunner(WL, ev)
    with SweepExecutor(ev, max_workers=2) as ex:
        par_pairs = run_trials(par_runner, cands, ex)
    assert [i for i, _ in par_pairs] == [0, 1]
    for (i, res), (_, name, _d) in zip(par_pairs, cands):
        assert par_runner.log[i].name == name
        assert par_runner.log[i].result["cost_s"] == res.cost_s


def test_run_trials_rejects_foreign_executor():
    runner = TrialRunner(WL, CountingEvaluator())
    with SweepExecutor(CountingEvaluator()) as ex:
        with pytest.raises(ValueError):
            run_trials(runner, [(default_config(), "x", None)], ex)


@pytest.mark.parametrize("crash", [{}, {"remat_policy": "full"}])
def test_sensitivity_parallel_equals_sequential(crash):
    base = default_config(shard_strategy="fsdp_tp")
    seq_runner = TrialRunner(WL, CountingEvaluator(crash_on=crash))
    seq = run_sensitivity(seq_runner, base)
    par_ev = CountingEvaluator(crash_on=crash)
    with SweepExecutor(par_ev, max_workers=4) as ex:
        par_runner = TrialRunner(WL, par_ev)
        par = run_sensitivity(par_runner, base, executor=ex)
    assert par.n_trials == seq.n_trials
    assert par.baseline_cost == seq.baseline_cost
    for a, b in zip(seq.impacts, par.impacts):
        assert (a.knob, a.values, a.crashes) == (b.knob, b.values, b.crashes)
        assert a.deviations_pct == pytest.approx(b.deviations_pct,
                                                 nan_ok=True)
    # identical log layout (names + notes), deterministic order
    assert [(e.name, e.note) for e in seq_runner.log] \
        == [(e.name, e.note) for e in par_runner.log]


@pytest.mark.parametrize("crash", [{}, {"remat_policy": "full"}])
def test_tree_parallel_equals_sequential(crash):
    base = default_config(shard_strategy="fsdp_tp")
    seq_runner = TrialRunner(WL, CountingEvaluator(crash_on=crash))
    seq = run_tuning(seq_runner, base, threshold=0.05)
    par_ev = CountingEvaluator(crash_on=crash)
    with SweepExecutor(par_ev, max_workers=4) as ex:
        par_runner = TrialRunner(WL, par_ev)
        par = run_tuning(par_runner, base, threshold=0.05, executor=ex)
    assert par.n_trials == seq.n_trials <= MAX_TRIALS
    assert par.final_cost == seq.final_cost
    assert par.final_config == seq.final_config
    assert par.accepted == seq.accepted
    assert [(e["name"], e["accepted"]) for e in seq.log] \
        == [(e["name"], e["accepted"]) for e in par.log]
