"""Calibration layer: documents XLA's while-body-once counting and
verifies the unroll-extrapolation recovers true per-layer costs."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import costmodel
from repro.configs import get_config, get_reduced


def _scan_flops(n, unroll):
    def f(x, ws):
        if unroll:
            for i in range(n):
                x = x @ ws[i]
            return x
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((n, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    return float(costmodel.cost_analysis_dict(compiled)["flops"])


def test_while_body_counted_once():
    """The raison d'etre of the calibration machinery."""
    assert _scan_flops(8, unroll=False) == pytest.approx(
        _scan_flops(2, unroll=False), rel=1e-3)
    assert _scan_flops(8, unroll=True) == pytest.approx(
        8 * 2 * 64**3, rel=1e-2)


def test_extrapolation_recovers_linear_cost():
    # measured at 1 and 3 units with outside=7, per_unit=2
    out = costmodel.extrapolate(7 + 2 * 1, 7 + 2 * 3, units=10)
    assert out == pytest.approx(7 + 2 * 10)
    # clamping: never negative per-unit
    assert costmodel.extrapolate(10.0, 8.0, units=100) == 10.0


def test_extrapolation_matches_direct_unrolled_compile():
    """Extrapolated flops from (1,3)-unit compiles == direct 6-unit
    unrolled compile (same graph family)."""
    v1 = _scan_flops(1, unroll=True)
    v3 = _scan_flops(3, unroll=True)
    v6 = _scan_flops(6, unroll=True)
    assert costmodel.extrapolate(v1, v3, 6) == pytest.approx(v6, rel=1e-2)


@pytest.mark.parametrize("arch", ["deepseek-coder-33b", "kimi-k2-1t-a32b",
                                  "zamba2-7b", "xlstm-1.3b",
                                  "seamless-m4t-medium"])
def test_calibration_points_shapes(arch):
    cfg = get_config(arch)
    points, units = costmodel.calibration_points(cfg)
    (c1, u1), (c3, u3) = points
    assert (u1, u3) == (1, 3)
    assert units >= 3
    # the small configs are structurally valid (spec builds)
    from repro.models.model import build_model
    for c in (c1, c3):
        build_model(c).param_shapes()


def test_model_flops_moe_counts_active_only():
    cfg = get_config("kimi-k2-1t-a32b")
    from repro.configs import get_shape
    dense_equiv = cfg.param_count()
    active = cfg.active_param_count()
    assert active < dense_equiv / 10          # 1T total, ~32B active
    mf = costmodel.model_flops(cfg, get_shape("train_4k"))
    assert mf == pytest.approx(6.0 * active * 256 * 4096)
