"""Trial-throughput engine: knob partition, two-level compile cache,
cached-vs-naive cost identity, multi-process disk safety.

The load-bearing invariant: the cache may only change HOW MANY compiles
a sweep pays for, never any observed cost — configs sharing a
compile_key() must compile to identical programs.  Since the campaign
fabric, the disk level is shared across worker *processes*: writes are
unique-tempfile + atomic-rename, and a torn entry is a miss, never a
crash."""
import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import threading

import pytest

from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.core.params import (ANALYTIC_KNOBS, COMPILE_KNOBS, DOMAINS,
                               TunableConfig, default_config)
from repro.core.trial import CompileCache, RooflineEvaluator, Workload

BASE = default_config(shard_strategy="fsdp_tp")


# ------------------------------------------------------------ partition
def test_partition_covers_every_field():
    fields = {f.name for f in dataclasses.fields(TunableConfig)}
    assert set(COMPILE_KNOBS) | set(ANALYTIC_KNOBS) == fields
    assert not set(COMPILE_KNOBS) & set(ANALYTIC_KNOBS)


@pytest.mark.parametrize("knob", ANALYTIC_KNOBS)
def test_analytic_knob_flip_shares_key(knob):
    """Every analytic-only knob flip keeps the compile key (any cell)."""
    dom = DOMAINS.get(knob, ("xla", "pallas"))
    alt = next(v for v in dom if v != getattr(BASE, knob))
    for kind in ("train", "prefill", "decode"):
        for family in ("dense", "moe", "ssm"):
            assert (BASE.replace(**{knob: alt}).compile_key(kind, family)
                    == BASE.compile_key(kind, family))


ALWAYS_COMPILE = ("compute_dtype", "shard_strategy", "attn_tp_fallback",
                  "seq_parallel", "unroll_layers")


@pytest.mark.parametrize("knob", ALWAYS_COMPILE)
def test_structural_knob_flip_misses(knob):
    """Knobs that reach every step function always change the key."""
    dom = DOMAINS.get(knob, (False, True))
    alt = next(v for v in dom if v != getattr(BASE, knob))
    for kind in ("train", "prefill", "decode"):
        for family in ("dense", "moe", "ssm"):
            assert (BASE.replace(**{knob: alt}).compile_key(kind, family)
                    != BASE.compile_key(kind, family))


def test_conditional_knob_reach():
    """Spot-check the per-cell canonicalizations against KNOB_REACH."""
    # train-only knobs vanish from serve keys but not train keys
    for knob, alt in [("microbatches", 4), ("remat_policy", "full"),
                      ("grad_comm_dtype", "bfloat16")]:
        flip = BASE.replace(shard_strategy="fsdp", **{knob: alt})
        base = BASE.replace(shard_strategy="fsdp")
        assert flip.compile_key("train", "dense") \
            != base.compile_key("train", "dense")
        assert flip.compile_key("decode", "dense") \
            == base.compile_key("decode", "dense")
    # KV dtype: serve-only, and never for the ssm family
    flip = BASE.replace(kv_cache_dtype="int8")
    assert flip.compile_key("decode", "dense") \
        != BASE.compile_key("decode", "dense")
    assert flip.compile_key("train", "dense") \
        == BASE.compile_key("train", "dense")
    assert flip.compile_key("decode", "ssm") \
        == BASE.compile_key("decode", "ssm")
    # MoE wire codec: moe family only
    flip = BASE.replace(comm_codec="int8")
    assert flip.compile_key("train", "moe") \
        != BASE.compile_key("train", "moe")
    assert flip.compile_key("train", "dense") \
        == BASE.compile_key("train", "dense")
    # grad-comm knobs are no-ops off the explicit path (fsdp_tp)
    flip = BASE.replace(grad_comm_dtype="bfloat16",
                        fuse_grad_collectives=True)
    assert flip.compile_key("train", "dense") \
        == BASE.compile_key("train", "dense")
    # prefill carry dtype: bf16 save changes the key under 'dots' ...
    flip = BASE.replace(remat_save_dtype="bfloat16")
    assert flip.compile_key("prefill", "dense") \
        != BASE.compile_key("prefill", "dense")
    # ... but not under 'none' (nothing is saved, carry = compute dtype)
    assert flip.replace(remat_policy="none").compile_key("prefill", "dense") \
        == BASE.replace(remat_policy="none").compile_key("prefill", "dense")
    # encdec prefill runs the encoder through the remat machinery:
    # both remat knobs stay in the key verbatim
    assert flip.compile_key("prefill", "encdec") \
        != BASE.compile_key("prefill", "encdec")
    assert BASE.replace(remat_policy="full").compile_key("prefill", "encdec") \
        != BASE.compile_key("prefill", "encdec")
    # ...but its decode path never touches remat
    assert BASE.replace(remat_policy="full").compile_key("decode", "encdec") \
        == BASE.compile_key("decode", "encdec")


# ---------------------------------------------------------- cache layer
def test_compile_cache_lru_and_disk(tmp_path):
    cc = CompileCache(directory=tmp_path, mem_entries=2)
    calls = []
    val = cc.get_or_build("a", lambda: calls.append(1) or {"x": 1})
    assert val == {"x": 1} and len(calls) == 1
    assert cc.get_or_build("a", lambda: calls.append(1) or {"x": 2}) \
        == {"x": 1}
    assert len(calls) == 1
    # fill past mem capacity; disk still serves evicted keys
    cc.get_or_build("b", lambda: {"x": "b"})
    cc.get_or_build("c", lambda: {"x": "c"})
    assert "a" not in cc._mem           # evicted from LRU
    assert cc.get_or_build("a", lambda: {"x": "FRESH"}) == {"x": 1}
    # a fresh cache over the same dir = level-2 hit, no rebuild
    cc2 = CompileCache(directory=tmp_path)
    assert cc2.get_or_build("c", lambda: {"x": "FRESH"}) == {"x": "c"}
    assert cc2.stats()["hits"] == 1 and cc2.stats()["misses"] == 0


def test_compile_cache_inflight_dedup():
    cc = CompileCache(use_disk=False)
    gate = threading.Event()
    calls = []

    def slow_build():
        calls.append(1)
        gate.wait(5)
        return {"v": len(calls)}

    out = [None] * 4
    def worker(i):
        out[i] = cc.get_or_build("k", slow_build)
    ts = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in ts:
        t.start()
    gate.set()
    for t in ts:
        t.join()
    assert calls == [1]                 # one build for four callers
    assert all(o == {"v": 1} for o in out)


def test_compile_cache_tolerates_torn_disk_entry(tmp_path):
    """A half-written entry (crashed writer, pre-atomic-rename era) is
    a miss: the reader rebuilds and atomically repairs the file."""
    cc = CompileCache(directory=tmp_path)
    (tmp_path / "k.json").write_text('{"x": 1, "trunc')
    assert cc.get_or_build("k", lambda: {"x": "rebuilt"}) \
        == {"x": "rebuilt"}
    # the torn file was repaired on disk: a fresh cache reads it
    assert CompileCache(directory=tmp_path) \
        .get_or_build("k", lambda: {"x": "NO"}) == {"x": "rebuilt"}
    # non-dict junk is equally a miss
    (tmp_path / "j.json").write_text("[1, 2]")
    assert cc.get_or_build("j", lambda: {"x": "j"}) == {"x": "j"}


def test_compile_cache_writes_are_atomic_unique_tempfiles(tmp_path):
    """No fixed .tmp path: concurrent same-key writers in different
    processes must never interleave bytes in one temp file."""
    cc = CompileCache(directory=tmp_path)
    cc.get_or_build("k", lambda: {"x": 1})
    leftovers = [p.name for p in tmp_path.iterdir()
                 if p.suffix == ".tmp"]
    assert leftovers == []               # temp was renamed into place
    assert json.loads((tmp_path / "k.json").read_text()) == {"x": 1}


_STRESS_CHILD = r"""
import json, random, sys, time
from repro.core.trial import CompileCache

cache_dir, out_path, seed = sys.argv[1], sys.argv[2], int(sys.argv[3])
rng = random.Random(seed)
cc = CompileCache(directory=cache_dir, mem_entries=2)  # force disk traffic
got = {}
for i in range(120):
    key = f"k{rng.randint(0, 7)}"

    def build(key=key):
        time.sleep(rng.random() * 0.002)
        return {"key": key, "payload": "x" * 4096}

    val = cc.get_or_build(key, build)
    assert val["key"] == key and len(val["payload"]) == 4096, val
    got[key] = val
json.dump(got, open(out_path, "w"))
"""


@pytest.mark.parametrize("n_procs", [2])
def test_compile_cache_two_process_stress(tmp_path, n_procs):
    """Satellite: two processes hammer one cache directory with
    overlapping keys.  Every read must return a complete entry (no
    torn pickles), and the directory must end consistent."""
    cache_dir = tmp_path / "cache"
    procs = []
    for i in range(n_procs):
        out = tmp_path / f"out{i}.json"
        env = dict(os.environ,
                   PYTHONPATH="src" + os.pathsep
                   + os.environ.get("PYTHONPATH", ""),
                   JAX_PLATFORMS="cpu")
        root = pathlib.Path(__file__).resolve().parents[1]
        procs.append((subprocess.Popen(
            [sys.executable, "-c", _STRESS_CHILD, str(cache_dir),
             str(out), str(i)], cwd=root,
            env=env, stderr=subprocess.PIPE), out))
    outs = []
    for p, out in procs:
        _, err = p.communicate(timeout=120)
        assert p.returncode == 0, err.decode()
        outs.append(json.load(open(out)))
    # both processes observed identical values per key
    for key in set(outs[0]) | set(outs[1]):
        vals = [o[key] for o in outs if key in o]
        assert all(v == vals[0] for v in vals)
    # the directory holds only complete JSON entries, no temp leftovers
    for p in cache_dir.iterdir():
        assert p.suffix == ".json", p
        assert json.loads(p.read_text())["key"] == p.stem


# ------------------------------------------- evaluator cost identity
class ReducedWorkload(Workload):
    """Reduced config + tiny shape on the host mesh (fast compiles)."""
    @property
    def cfg(self):
        return get_reduced(self.arch)

    @property
    def shp(self):
        return ShapeConfig("mini", 64, 4, self._kind)

    def __init__(self, arch, kind="train"):
        super().__init__(arch, f"mini_{kind}")
        self._kind = kind


def _host_mesh_factory(*, multi_pod=False):
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh()


def test_crash_before_compile_not_reported_cached():
    """Regression: a trial that dies before reaching any calibration
    compile (here: in the mesh factory) used to report cached=True
    because it paid zero compiles — but it was never served from the
    cache, it crashed."""
    def boom_mesh_factory(*, multi_pod=False):
        raise RuntimeError("no devices")
    ev = RooflineEvaluator(mesh_factory=boom_mesh_factory,
                           compile_cache=CompileCache(use_disk=False))
    res = ev(Workload("smollm-135m", "train_4k"), default_config())
    assert res.crashed and res.compiles == 0
    assert not res.cached
    assert "no devices" in res.error


def test_cache_served_trial_still_reported_cached(tmp_path):
    """The complement: a repeat trial genuinely served from the cache
    keeps cached=True."""
    wl = ReducedWorkload("smollm-135m", "train")
    ev = RooflineEvaluator(mesh_factory=_host_mesh_factory,
                           compile_cache=CompileCache(directory=tmp_path))
    first = ev(wl, default_config())
    assert first.compiles > 0 and not first.cached
    second = ev(wl, default_config())
    assert second.compiles == 0 and second.cached


@pytest.mark.parametrize("kind", ["train", "prefill"])
def test_cached_vs_uncached_costs_identical(tmp_path, kind):
    """Regression: the engine never changes an observed cost.  Sweep a
    mix of analytic and compile-relevant knobs on a reduced cell and
    compare against the compile-every-time evaluator bit for bit."""
    wl = ReducedWorkload("smollm-135m", kind)
    naive = RooflineEvaluator(mesh_factory=_host_mesh_factory,
                              use_cache=False)
    engine = RooflineEvaluator(
        mesh_factory=_host_mesh_factory,
        compile_cache=CompileCache(directory=tmp_path))
    base = default_config()
    sweep = [base,
             base.replace(attn_block_q=512, attn_block_kv=512),
             base.replace(comm_codec="int8"),
             base.replace(kv_cache_dtype="int8"),
             base.replace(microbatches=2),
             base.replace(compute_dtype="bfloat16")]
    for rt in sweep:
        rn, re_ = naive(wl, rt), engine(wl, rt)
        assert rn.cost_s == re_.cost_s, rt.describe_delta(base)
        assert rn.crashed == re_.crashed
        assert rn.roofline == re_.roofline
    # the engine shared compiles: strictly fewer than 4 per trial
    assert engine.total_compiles < naive.total_compiles
    # analytic-only flips were free
    assert engine.total_compiles <= 4 * len(
        {rt.compile_key(wl.shp.kind, wl.cfg.family) for rt in sweep})


# ------------------------------------------- failure-class memoization
def test_cache_transient_entry_never_memoized(tmp_path):
    """Regression: an environment hiccup during a build used to be
    memoized exactly like a deterministic program failure, permanently
    remembering the key as crashed.  Transient entries must be returned
    to their waiters but never cached at either level."""
    from repro.core.trial import FAILURE_DETERMINISTIC, FAILURE_TRANSIENT
    cc = CompileCache(directory=tmp_path)
    calls = []

    def flaky_build():
        calls.append(1)
        return {"error": "OSError: NFS hiccup",
                "failure": FAILURE_TRANSIENT}

    assert cc.get_or_build("k", flaky_build)["failure"] \
        == FAILURE_TRANSIENT
    assert cc.get_or_build("k", flaky_build)["failure"] \
        == FAILURE_TRANSIENT
    assert len(calls) == 2                  # rebuilt, not replayed
    assert not (tmp_path / "k.json").exists()
    # deterministic build errors ARE memoized — in-memory only (they
    # must not outlive the run that observed them)
    det = []

    def det_build():
        det.append(1)
        return {"error": "ValueError: bad shape",
                "failure": FAILURE_DETERMINISTIC}

    cc.get_or_build("d", det_build)
    cc.get_or_build("d", det_build)
    assert len(det) == 1
    assert not (tmp_path / "d.json").exists()


def test_transient_compile_fault_not_memoized_by_evaluator(
        tmp_path, monkeypatch):
    """Regression (satellite): one OSError during a calibration compile
    crashes that trial as *transient*, and the next evaluation of the
    same config rebuilds and succeeds instead of replaying the fault."""
    from repro.core.trial import FAILURE_TRANSIENT
    wl = ReducedWorkload("smollm-135m", "train")
    ev = RooflineEvaluator(mesh_factory=_host_mesh_factory,
                           compile_cache=CompileCache(directory=tmp_path))
    real = ev._roofline_at
    fails = []

    def flaky(*a, **k):
        if not fails:
            fails.append(1)
            raise OSError("disk cache hiccup")
        return real(*a, **k)

    monkeypatch.setattr(ev, "_roofline_at", flaky)
    first = ev(wl, default_config())
    assert first.crashed and first.failure == FAILURE_TRANSIENT
    assert "disk cache hiccup" in first.error
    second = ev(wl, default_config())
    assert not second.crashed and second.compiles > 0


def test_deterministic_compile_failure_stays_memoized(
        tmp_path, monkeypatch):
    """The complement: a program that deterministically fails to build
    is remembered — repeat trials are scored from the memo for free."""
    from repro.core.trial import FAILURE_DETERMINISTIC
    wl = ReducedWorkload("smollm-135m", "train")
    ev = RooflineEvaluator(mesh_factory=_host_mesh_factory,
                           compile_cache=CompileCache(directory=tmp_path))
    calls = []

    def broken(*a, **k):
        calls.append(1)
        raise RuntimeError("bad lowering")

    monkeypatch.setattr(ev, "_roofline_at", broken)
    first = ev(wl, default_config())
    second = ev(wl, default_config())
    assert first.crashed and first.failure == FAILURE_DETERMINISTIC
    assert second.crashed and "bad lowering" in second.error
    assert len(calls) == 1                  # served from the memo
    assert second.compiles == 0 and second.cached
