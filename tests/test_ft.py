"""Fault-tolerance layer: straggler detection, preemption flow,
elastic remesh + resharded restore, end-to-end restart equivalence."""
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.ft.elastic import remesh, survivors_mesh
from repro.ft.preemption import PreemptionHandler
from repro.ft.straggler import StragglerDetector


def test_straggler_flags_slow_host():
    hits = []
    det = StragglerDetector(factor=2.0, window=8, min_samples=4,
                            action=lambda h, m, f: hits.append(h))
    for step in range(8):
        for h in ("h0", "h1", "h2", "h3"):
            det.heartbeat(h, step, 1.0 if h != "h2" else 5.0)
    flagged = det.check()
    assert flagged == ["h2"] and hits == ["h2"]


def test_straggler_no_false_positive_on_noise():
    det = StragglerDetector(factor=2.0, window=16, min_samples=4)
    rng = np.random.RandomState(0)
    for step in range(16):
        for h in ("h0", "h1", "h2"):
            det.heartbeat(h, step, 1.0 + rng.rand() * 0.3)
    assert det.check() == []


def test_preemption_handler_flag():
    pre = PreemptionHandler(signals=(signal.SIGUSR1,)).install()
    try:
        assert not pre.requested()
        signal.raise_signal(signal.SIGUSR1)
        assert pre.requested()
    finally:
        pre.uninstall()


def test_remesh_preserves_model_axis():
    m = remesh(1, model_axis=1)
    assert dict(m.shape) == {"data": 1, "model": 1}


def test_survivors_mesh_shrinks_data_axis():
    import numpy as np
    from jax.sharding import Mesh
    devs = np.array(jax.devices() * 8)[:8].reshape(4, 2)
    old = Mesh(devs, ("data", "model"))
    # losing 2 devices must keep model=2 and shrink data
    new, n = survivors_mesh(old, lost=2)
    assert new.shape["model"] in (1, 2) and n <= 6


def test_elastic_restore_onto_new_mesh(tmp_path):
    """Checkpoint written under one layout restores under another."""
    tree = {"w": jnp.arange(64.0).reshape(8, 8),
            "b": jnp.ones((8,))}
    ckpt.save(tmp_path, 3, tree)
    target = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), tree)
    restored = ckpt.restore(tmp_path, 3, target, shardings=None)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


@pytest.mark.slow
def test_preempt_restart_equivalence(tmp_path):
    """Train 8 steps straight vs 4 steps -> 'preempt' -> resume 4 more:
    identical final loss (exact data pipeline restart)."""
    env_args = ["--arch", "smollm-135m", "--reduced", "--batch", "4",
                "--seq", "32", "--ckpt-interval", "1",
                "--log-interval", "1"]

    def run(steps, ckdir):
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--steps",
             str(steps), "--ckpt-dir", str(ckdir)] + env_args,
            capture_output=True, text=True, timeout=600,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                 "HOME": "/root",
                 # keep the child off the TPU driver: with libtpu baked
                 # into the image but no hardware attached, backend
                 # autodetection blocks for minutes before falling back
                 "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
            cwd="/root/repo")
        assert out.returncode == 0, out.stderr[-2000:]
        losses = [l for l in out.stdout.splitlines() if "loss" in l]
        return losses[-1].split("loss")[1].split()[0]

    straight = run(8, tmp_path / "a")
    run(4, tmp_path / "b")
    resumed = run(8, tmp_path / "b")
    assert straight == resumed, (straight, resumed)
