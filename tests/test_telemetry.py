"""Campaign telemetry (core/telemetry.py): the event bus, spans,
metrics folding, Chrome-trace export and the leveled fleet logger.

The load-bearing invariant: **telemetry observes, never decides.**  A
campaign with tracing enabled must be bit-identical (logs, budgets,
final configs) to the same campaign without it, and a disabled bus
must be a zero-allocation no-op that never creates a file.
"""
import io
import json
import threading

import pytest

from repro.core import telemetry
from repro.core.campaign import Campaign, CellSpec, tuning_fingerprint
from repro.core.executor import SweepExecutor
from repro.core.params import default_config
from repro.core.trial import TrialResult, Workload

WL = Workload("smollm-135m", "train_4k")


def surface(wl, rt):
    """Deterministic cost surface with one crash region."""
    if rt.remat_policy == "full" and wl.arch == "glm4-9b":
        return TrialResult(cost_s=float("inf"), crashed=True)
    c = 100.0 + 3.0 * len(wl.arch)
    if rt.compute_dtype == "bfloat16":
        c *= 0.7
    if rt.remat_policy == "none":
        c *= 0.85
    return TrialResult(cost_s=round(c, 6))


def rec(kind, ts, **kw):
    base = {"v": 1, "kind": kind, "ts": ts,
            "worker": kw.pop("worker", "w0"), "pid": 1, "thread": "main"}
    base.update(kw)
    return base


# ---------------------------------------------------------- event bus
def test_disabled_bus_is_noop(tmp_path):
    t = telemetry.Telemetry(tmp_path, enabled=False)
    t.emit("trial", cell="c")
    assert t.span("trial") is telemetry._NULL_SPAN   # no allocation
    with t.span("trial") as sp:
        sp.note(cost_s=1.0)
    assert not (tmp_path / telemetry.EVENTS_NAME).exists()
    assert telemetry.read_events(tmp_path) == []
    # a directory-less bus is disabled no matter what was asked for
    assert not telemetry.Telemetry(None, enabled=True).enabled


def test_emit_schema_fields(tmp_path):
    t = telemetry.Telemetry(tmp_path, worker="w7")
    t.emit("retry", cell="c", attempt=2)
    (r,) = telemetry.read_events(tmp_path)
    assert r["v"] == telemetry.SCHEMA_VERSION
    assert r["kind"] == "retry" and r["cell"] == "c" and r["attempt"] == 2
    assert r["worker"] == "w7"
    assert isinstance(r["ts"], float) and isinstance(r["pid"], int)
    assert r["thread"] == threading.current_thread().name


def test_span_duration_and_parent_linkage(tmp_path):
    t = telemetry.Telemetry(tmp_path, worker="w0")
    with t.span("trial", cell="c") as outer:
        with t.span("compile", key="k"):
            pass
        t.emit("cache.miss", key="k")
    records = telemetry.read_events(tmp_path)
    by_kind = {r["kind"]: r for r in records}
    trial, compile_, miss = (by_kind["trial"], by_kind["compile"],
                             by_kind["cache.miss"])
    assert trial["span"] == outer.id and "parent" not in trial
    assert compile_["parent"] == trial["span"]
    assert miss["parent"] == trial["span"]
    assert trial["dur_s"] >= compile_["dur_s"] >= 0.0
    # the span's ts is its *start*: it precedes the nested compile's
    assert trial["ts"] <= compile_["ts"]


def test_span_note_attaches_fields(tmp_path):
    t = telemetry.Telemetry(tmp_path)
    with t.span("trial", cell="c") as sp:
        sp.note(cost_s=1.5, crashed=False)
    (r,) = telemetry.read_events(tmp_path)
    assert r["cost_s"] == 1.5 and r["crashed"] is False


def test_emit_never_raises_into_the_caller(tmp_path):
    blocker = tmp_path / "blocker"
    blocker.write_text("")               # a *file* where a dir must go
    t = telemetry.Telemetry(blocker)     # events path is unwritable
    t.emit("trial", cell="c")            # OSError swallowed
    with t.span("trial"):
        pass


def test_read_events_skips_torn_and_garbage_lines(tmp_path):
    path = tmp_path / telemetry.EVENTS_NAME
    good = json.dumps(rec("trial", 1.0))
    path.write_text(good + "\n" + "{torn-lin" + "\nnot json\n"
                    + json.dumps(["not", "a", "dict"]) + "\n"
                    + good + "\n")
    records = telemetry.read_events(tmp_path)
    assert len(records) == 2
    assert all(r["kind"] == "trial" for r in records)
    assert telemetry.read_events(tmp_path / "nope") == []


def test_install_current_uninstall():
    assert telemetry.current() is telemetry.NULL
    t = telemetry.Telemetry(None, enabled=False)
    try:
        assert telemetry.install(t) is t
        assert telemetry.current() is t
    finally:
        telemetry.uninstall()
    assert telemetry.current() is telemetry.NULL


# ----------------------------------------------------- executor events
def test_executor_emits_trial_spans(tmp_path):
    t = telemetry.Telemetry(tmp_path, worker="w0")
    base = default_config()
    with SweepExecutor(surface, max_workers=2, telemetry=t) as ex:
        ex.submit(WL, base).result()
        ex.submit(WL, base.replace(compute_dtype="bfloat16")).result()
    trials = [r for r in telemetry.read_events(tmp_path)
              if r["kind"] == "trial"]
    assert len(trials) == 2
    assert all(r["cell"] == WL.key() and "span" in r and "config" in r
               and r["dur_s"] >= 0.0 for r in trials)
    costs = sorted(r["cost_s"] for r in trials)
    assert costs == sorted((surface(WL, base).cost_s,
                            surface(WL, base.replace(
                                compute_dtype="bfloat16")).cost_s))


def test_crashed_trial_event_has_no_infinite_cost(tmp_path):
    """JSON cannot carry inf: a crashed trial's event records
    crashed=True and *omits* cost_s instead of emitting Infinity."""
    t = telemetry.Telemetry(tmp_path)
    crash = Workload("glm4-9b", "train_4k")
    cfg = default_config().replace(remat_policy="full")
    with SweepExecutor(surface, max_workers=2, telemetry=t) as ex:
        res = ex.submit(crash, cfg).result()
    assert res.crashed
    (r,) = [r for r in telemetry.read_events(tmp_path)
            if r["kind"] == "trial"]
    assert r["crashed"] is True and "cost_s" not in r
    json.dumps(r, allow_nan=False)       # strict-JSON consumers survive


def test_executor_retry_events(tmp_path):
    calls = []

    def flaky(wl, rt):
        calls.append(rt)
        if len(calls) == 1:
            raise OSError("transient")
        return TrialResult(cost_s=1.0)

    t = telemetry.Telemetry(tmp_path)
    with SweepExecutor(flaky, max_workers=2, max_retries=2,
                       retry_backoff_s=0.001, telemetry=t) as ex:
        res = ex.submit(WL, default_config()).result()
    assert not res.crashed and res.retries == 1
    kinds = [r["kind"] for r in telemetry.read_events(tmp_path)]
    assert kinds.count("retry") == 1 and kinds.count("trial") == 1


# -------------------------------------------------------- metrics fold
def synthetic_records():
    return [
        rec("trial", 0.0, dur_s=1.0, cell="c", cost_s=2.0),
        rec("trial", 1.0, dur_s=1.0, cell="c", cost_s=1.0),
        rec("trial", 2.0, dur_s=1.0, cell="c", crashed=True,
            worker="w1"),
        rec("compile", 0.2, dur_s=0.5),
        rec("cache.hit", 2.5), rec("cache.miss", 2.6),
        rec("retry", 2.7), rec("lease.claim", 0.0),
        rec("lease.steal", 2.8), rec("quarantine.strike", 2.9),
    ]


def test_fold_metrics_counters_gauges_attribution():
    m = telemetry.fold_metrics(synthetic_records())
    c, g, a = m["counters"], m["gauges"], m["attribution"]
    assert m["events"] == 10
    assert c["trials"] == 3 and c["crashes"] == 1
    assert c["cache_hits"] == 1 and c["cache_misses"] == 1
    assert c["retries"] == 1 and c["lease_steals"] == 1
    assert c["quarantine_strikes"] == 1
    assert g["cache_hit_rate"] == 0.5
    assert g["workers"] == 2
    assert g["crash_rate"] == pytest.approx(1 / 3, abs=1e-3)
    assert m["window"]["wall_s"] == 3.0
    assert g["trials_per_s"] == 1.0
    assert a["trial_s"] == 3.0 and a["compile_s"] == 0.5
    assert a["eval_s"] == 2.5
    assert m["histograms"]["trial_dur_s"]["le_1s"] == 3


def test_fold_metrics_per_cell_first_improvement():
    m = telemetry.fold_metrics(synthetic_records())
    cell = m["per_cell"]["c"]
    assert cell["trials"] == 3
    assert cell["baseline_cost_s"] == 2.0
    assert cell["best_cost_s"] == 1.0
    # the improving trial (cost 1.0 < baseline 2.0) *finished* at
    # ts+dur = 2.0, and the cell's first event was at 0.0
    assert cell["first_improvement_s"] == 2.0


def test_fold_metrics_per_worker_utilization():
    m = telemetry.fold_metrics(synthetic_records())
    assert m["per_worker"]["w0"]["trials"] == 2
    assert m["per_worker"]["w0"]["busy_s"] == 2.0
    assert m["per_worker"]["w0"]["utilization"] \
        == pytest.approx(2.0 / 3.0, abs=1e-3)
    assert m["per_worker"]["w1"]["trials"] == 1


def test_fold_metrics_empty_and_no_lookups():
    m = telemetry.fold_metrics([])
    assert m["events"] == 0 and m["counters"]["trials"] == 0
    assert m["gauges"]["cache_hit_rate"] is None   # 0/0 is unknown
    assert m["window"]["wall_s"] == 0.0


def test_publish_and_load_metrics(tmp_path):
    assert telemetry.publish_metrics(tmp_path) is None   # no events
    assert not (tmp_path / telemetry.METRICS_NAME).exists()
    t = telemetry.Telemetry(tmp_path)
    t.emit("trial", ts=1.0, dur_s=0.5, cell="c", cost_s=1.0)
    published = telemetry.publish_metrics(tmp_path)
    assert published["counters"]["trials"] == 1
    assert telemetry.load_metrics(tmp_path) == published


# ------------------------------------------------------- chrome trace
def test_chrome_trace_tracks_slices_instants():
    trace = telemetry.chrome_trace(synthetic_records())
    events = trace["traceEvents"]
    json.dumps(trace, allow_nan=False)   # valid strict JSON
    meta = [e for e in events if e["ph"] == "M"]
    assert {e["args"]["name"] for e in meta
            if e["name"] == "process_name"} == {"w0", "w1"}
    slices = [e for e in events if e["ph"] == "X"]
    assert sum(e["cat"] == "trial" for e in slices) == 3
    assert all(e["dur"] > 0 for e in slices)
    instants = [e for e in events if e["ph"] == "i"]
    assert {e["cat"] for e in instants} >= {"cache.hit", "retry",
                                            "lease.steal"}
    assert all(e["s"] == "t" for e in instants)
    # timestamps are µs relative to the earliest event
    assert min(e["ts"] for e in events if e["ph"] != "M") == 0.0


def test_export_chrome_trace(tmp_path):
    t = telemetry.Telemetry(tmp_path)
    t.emit("trial", ts=1.0, dur_s=0.5, cell="c", cost_s=1.0)
    t.emit("lease.claim", ts=0.5, cell="c")
    out = tmp_path / "out" / "trace.json"
    n = telemetry.export_chrome_trace(tmp_path, out)
    assert n == 2
    trace = json.loads(out.read_text())
    assert any(e.get("ph") == "X" for e in trace["traceEvents"])


# ---------------------------------------------- the bit-identity law
CELLS = [CellSpec("smollm-135m", "train_4k"),
         CellSpec("glm4-9b", "train_4k")]


def _run_campaign(ckpt, telemetry_bus=None):
    camp = Campaign(CELLS, threshold=0.05, evaluator=surface,
                    baseline_factory=lambda spec: default_config(
                        shard_strategy="fsdp_tp", attn_impl="pallas"),
                    checkpoint_dir=ckpt, max_workers=2,
                    telemetry=telemetry_bus)
    return camp.run()


def test_campaign_bit_identical_with_telemetry_on_or_off(tmp_path):
    """The hard invariant: tracing must not perturb a single decision.
    Full report equality — logs, trial counts, budgets, final configs —
    not just the fingerprint."""
    plain = _run_campaign(tmp_path / "plain")
    bus = telemetry.install(telemetry.Telemetry(tmp_path / "traced",
                                                worker="w0"))
    try:
        traced = _run_campaign(tmp_path / "traced", telemetry_bus=bus)
    finally:
        telemetry.uninstall()
    assert list(traced) == list(plain)
    for key in plain:
        assert traced[key].__dict__ == plain[key].__dict__
        assert tuning_fingerprint(traced[key]) \
            == tuning_fingerprint(plain[key])
    # and the traced run actually recorded its evidence
    records = telemetry.read_events(tmp_path / "traced")
    trials = [r for r in records if r["kind"] == "trial"]
    assert len(trials) == sum(r.n_trials for r in plain.values())
    assert {r["kind"] for r in records} >= {"trial", "cell.activate",
                                            "cell.done"}
    # ...while the plain run wrote nothing
    assert telemetry.read_events(tmp_path / "plain") == []
    assert not (tmp_path / "plain" / telemetry.EVENTS_NAME).exists()


# -------------------------------------------------------------- logger
def test_logger_levels_and_prefix(monkeypatch):
    monkeypatch.delenv(telemetry.LOG_ENV, raising=False)
    out = io.StringIO()
    log = telemetry.get_logger("w3")
    log.stream = out
    log.debug("hidden")                  # default level is info
    log.info("visible")
    log.warn("loud")
    lines = out.getvalue().splitlines()
    assert lines == ["[info] [w3] visible", "[warn] [w3] loud"]


def test_logger_env_level(monkeypatch):
    monkeypatch.setenv(telemetry.LOG_ENV, "warn")
    out = io.StringIO()
    log = telemetry.Logger(prefix="w0", stream=out)
    log.info("hidden")
    log.warn("shown")
    assert out.getvalue() == "[warn] [w0] shown\n"
    monkeypatch.setenv(telemetry.LOG_ENV, "debug")
    log2 = telemetry.Logger(stream=out)
    log2.debug("now visible")
    assert out.getvalue().endswith("[debug] now visible\n")


def test_logger_never_raises_on_dead_stream():
    class Dead:
        def write(self, *_):
            raise OSError("broken pipe")

        def flush(self):
            raise OSError("broken pipe")

    log = telemetry.Logger(prefix="w0", stream=Dead())
    log.warn("into the void")            # swallowed
