"""Property-style tests on the paper's tuner invariants.

Formerly hypothesis-based; rewritten as seeded parametrized cases so the
suite has no hard dependency on `hypothesis` (satellite of the
trial-throughput PR).  Each seed deterministically generates one
synthetic cost surface over the knob space."""
import math

import numpy as np
import pytest

from repro.core.executor import run_trials
from repro.core.params import (DOMAINS, SENSITIVITY_SWEEP, TunableConfig,
                               default_config, exhaustive_size)
from repro.core.sensitivity import run_sensitivity
from repro.core.tree import (MAX_TRIALS, Stage, TreeCursor, default_tree,
                             run_tuning)
from repro.core.trial import TrialResult, TrialRunner, Workload

WL = Workload("smollm-135m", "train_4k")

KNOB_WEIGHTS = [0.5, 0.7, 0.9, 0.97, 1.0, 1.05, 1.3, 2.0]


def synth_evaluator(weights: dict, crash_on: dict):
    """Deterministic synthetic cost surface over the knob space."""
    def ev(wl, rt):
        for k, v in crash_on.items():
            if getattr(rt, k) == v:
                return TrialResult(cost_s=float("inf"), crashed=True)
        c = 100.0
        for (k, v), w in weights.items():
            if getattr(rt, k) == v:
                c *= w
        return TrialResult(cost_s=c)
    return ev


def cost_surface(seed: int):
    """Seeded analogue of the old hypothesis strategy: random weight per
    non-default knob value, optional crash region."""
    rng = np.random.RandomState(seed)
    weights = {}
    for k, dom in DOMAINS.items():
        for v in dom[1:]:
            weights[(k, v)] = KNOB_WEIGHTS[rng.randint(len(KNOB_WEIGHTS))]
    crash = {}
    if rng.rand() < 0.5:
        crash["remat_policy"] = "full"
    return weights, crash


@pytest.mark.parametrize("seed", range(30))
@pytest.mark.parametrize("threshold", [0.0, 0.05, 0.10])
def test_tree_invariants(seed, threshold):
    weights, crash = cost_surface(seed)
    runner = TrialRunner(WL, synth_evaluator(weights, crash))
    baseline = default_config(shard_strategy="fsdp_tp")
    rep = run_tuning(runner, baseline, threshold=threshold)
    # (1) the paper's run budget
    assert rep.n_trials <= MAX_TRIALS
    # (2) final never worse than baseline under the same evaluator
    assert rep.final_cost <= rep.baseline_cost + 1e-9
    # (3) the final config's cost matches an independent evaluation
    final = TunableConfig(**rep.final_config)
    res = synth_evaluator(weights, crash)(WL, final)
    assert not res.crashed
    assert math.isclose(res.cost_s, rep.final_cost, rel_tol=1e-9)
    # (4) every accepted stage actually improved past the threshold
    log = rep.log
    costs = [e["result"]["cost_s"] for e in log]
    assert costs[0] == rep.baseline_cost or math.isinf(rep.baseline_cost)


@pytest.mark.parametrize("seed", range(20))
def test_sensitivity_invariants(seed):
    weights, crash = cost_surface(seed)
    runner = TrialRunner(WL, synth_evaluator(weights, crash))
    rep = run_sensitivity(runner, default_config(shard_strategy="fsdp_tp"))
    for imp in rep.impacts:
        # mean |%| is non-negative; crashes excluded from the mean
        assert imp.mean_abs_pct >= 0.0
        assert imp.crashes == sum(1 for d in imp.deviations_pct if d != d)
        # knobs with weight 1.0 everywhere have ~0 impact
        if all(weights.get((imp.knob, v), 1.0) == 1.0 for v in imp.values) \
                and not imp.crashes and imp.knob not in crash:
            assert imp.mean_abs_pct == pytest.approx(0.0, abs=1e-9)


def test_tree_beats_exhaustive_budget():
    """The whole point: <=10 trials vs the exhaustive grid."""
    assert exhaustive_size() >= 512          # paper quotes 2^9
    for kind in ("train", "prefill", "decode"):
        stages = default_tree(kind)
        n_alts = sum(len(s.alternatives) for s in stages)
        assert n_alts + 1 <= MAX_TRIALS + 1


def test_crashed_baseline_recovers():
    """If the default config crashes, any fitting config is accepted."""
    def ev(wl, rt):
        if rt.remat_policy == "dots":          # default crashes
            return TrialResult(cost_s=float("inf"), crashed=True)
        return TrialResult(cost_s=10.0)
    runner = TrialRunner(WL, ev)
    rep = run_tuning(runner, default_config(), threshold=0.05)
    assert rep.final_cost == 10.0
    assert any("memoryFraction" in a for a in rep.accepted)


@pytest.mark.parametrize("threshold", [0.05, 0.10])
def test_crashed_baseline_first_viable_accepted(threshold):
    """baseline cost_s = inf -> the first viable candidate must be
    acceptable regardless of the relative-improvement threshold (no
    finite cost can beat inf by a percentage)."""
    def ev(wl, rt):
        if rt.compute_dtype == "float32":       # only the baseline
            return TrialResult(cost_s=float("inf"), crashed=True)
        return TrialResult(cost_s=1e6)          # huge but finite
    runner = TrialRunner(WL, ev)
    rep = run_tuning(runner, default_config(), threshold=threshold)
    assert rep.baseline_cost == float("inf")
    assert rep.log[0]["result"]["crashed"]
    assert rep.log[0]["accepted"] is True       # baseline row stays marked
    # stage 1 (serializer -> bf16) is the first viable candidate
    assert rep.accepted[0].startswith("serializer")
    assert rep.log[1]["accepted"] is True
    assert rep.final_cost == 1e6


# ------------------------------------------------------------ TreeCursor
def test_cursor_propose_absorb_protocol():
    runner = TrialRunner(WL, synth_evaluator({}, {}))
    cursor = TreeCursor(runner, default_config(shard_strategy="fsdp_tp"))
    with pytest.raises(RuntimeError):
        cursor.absorb([], [])                   # nothing proposed yet
    batch = cursor.propose()
    assert [c.name for c in batch] == ["baseline"]
    with pytest.raises(RuntimeError):
        cursor.propose()                        # batch not absorbed yet
    pairs = run_trials(runner, [c.as_trial() for c in batch])
    with pytest.raises(ValueError):
        cursor.absorb([r for _, r in pairs], [])    # length mismatch
    cursor.absorb([r for _, r in pairs], [i for i, _ in pairs])
    assert not cursor.done
    while True:
        batch = cursor.propose()
        if not batch:
            break
        pairs = run_trials(runner, [c.as_trial() for c in batch])
        cursor.absorb([r for _, r in pairs], [i for i, _ in pairs])
    assert cursor.done and cursor.propose() == []
    assert cursor.report().n_trials == runner.n_trials <= MAX_TRIALS


@pytest.mark.parametrize("seed", range(10))
def test_cursor_replay_reconstructs_walk(seed):
    """The resume invariant: replaying a walk's recorded results through
    a fresh cursor reproduces the identical report (core/campaign.py
    relies on exactly this)."""
    weights, crash = cost_surface(seed)
    runner = TrialRunner(WL, synth_evaluator(weights, crash))
    baseline = default_config(shard_strategy="fsdp_tp")
    ref = run_tuning(runner, baseline, threshold=0.05)
    # replay: no evaluator calls, results served from the recorded log
    replay_runner = TrialRunner(WL, lambda wl, rt: (_ for _ in ()).throw(
        AssertionError("replay must not evaluate")))
    cursor = TreeCursor(replay_runner, baseline, threshold=0.05)
    stored = list(ref.log)
    while True:
        batch = cursor.propose()
        if not batch:
            break
        start = replay_runner.n_trials
        results, indices = [], []
        for c, entry in zip(batch, stored[start:start + len(batch)]):
            assert entry["config"] == c.config.as_dict()
            res = TrialResult(**entry["result"])
            replay_runner.record(c.config, c.name, res, c.delta)
            results.append(res)
            indices.append(replay_runner.n_trials - 1)
        cursor.absorb(results, indices)
    assert cursor.report().__dict__ == ref.__dict__


def test_duplicate_configs_do_not_cross_annotate():
    """Two alternatives lowering to the same config (and identical
    configs across stages) must be annotated independently, by log
    index — not by config equality."""
    # attn_block_q=128 is the default: both alts build the same config
    stages = [Stage("dup", "spark.dup",
                    [dict(microbatches=2),
                     dict(microbatches=2, attn_block_q=128)]),
              Stage("again", "spark.again", [dict(microbatches=2)])]
    def ev(wl, rt):
        return TrialResult(cost_s=50.0 if rt.microbatches == 2 else 100.0)
    runner = TrialRunner(WL, ev)
    rep = run_tuning(runner, default_config(), threshold=0.05,
                     stages=stages)
    dup_entries = [e for e in rep.log if e["name"] == "dup"]
    assert len(dup_entries) == 2
    # exactly the winner is accepted, its identical twin is rejected
    assert [e["accepted"] for e in dup_entries] == [True, False]
    # stage "again" is a no-op on the new incumbent: never evaluated
    assert not [e for e in rep.log if e["name"] == "again"]
    assert rep.n_trials == 3


def test_config_validation():
    with pytest.raises(ValueError):
        default_config(compute_dtype="float64")
    c = default_config()
    assert c.describe_delta(c.replace(microbatches=4)) == "microbatches=4"
