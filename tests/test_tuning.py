"""Property-style tests on the paper's tuner invariants.

Formerly hypothesis-based; rewritten as seeded parametrized cases so the
suite has no hard dependency on `hypothesis` (satellite of the
trial-throughput PR).  Each seed deterministically generates one
synthetic cost surface over the knob space."""
import math

import numpy as np
import pytest

from repro.core.params import (DOMAINS, SENSITIVITY_SWEEP, TunableConfig,
                               default_config, exhaustive_size)
from repro.core.sensitivity import run_sensitivity
from repro.core.tree import MAX_TRIALS, default_tree, run_tuning
from repro.core.trial import TrialResult, TrialRunner, Workload

WL = Workload("smollm-135m", "train_4k")

KNOB_WEIGHTS = [0.5, 0.7, 0.9, 0.97, 1.0, 1.05, 1.3, 2.0]


def synth_evaluator(weights: dict, crash_on: dict):
    """Deterministic synthetic cost surface over the knob space."""
    def ev(wl, rt):
        for k, v in crash_on.items():
            if getattr(rt, k) == v:
                return TrialResult(cost_s=float("inf"), crashed=True)
        c = 100.0
        for (k, v), w in weights.items():
            if getattr(rt, k) == v:
                c *= w
        return TrialResult(cost_s=c)
    return ev


def cost_surface(seed: int):
    """Seeded analogue of the old hypothesis strategy: random weight per
    non-default knob value, optional crash region."""
    rng = np.random.RandomState(seed)
    weights = {}
    for k, dom in DOMAINS.items():
        for v in dom[1:]:
            weights[(k, v)] = KNOB_WEIGHTS[rng.randint(len(KNOB_WEIGHTS))]
    crash = {}
    if rng.rand() < 0.5:
        crash["remat_policy"] = "full"
    return weights, crash


@pytest.mark.parametrize("seed", range(30))
@pytest.mark.parametrize("threshold", [0.0, 0.05, 0.10])
def test_tree_invariants(seed, threshold):
    weights, crash = cost_surface(seed)
    runner = TrialRunner(WL, synth_evaluator(weights, crash))
    baseline = default_config(shard_strategy="fsdp_tp")
    rep = run_tuning(runner, baseline, threshold=threshold)
    # (1) the paper's run budget
    assert rep.n_trials <= MAX_TRIALS
    # (2) final never worse than baseline under the same evaluator
    assert rep.final_cost <= rep.baseline_cost + 1e-9
    # (3) the final config's cost matches an independent evaluation
    final = TunableConfig(**rep.final_config)
    res = synth_evaluator(weights, crash)(WL, final)
    assert not res.crashed
    assert math.isclose(res.cost_s, rep.final_cost, rel_tol=1e-9)
    # (4) every accepted stage actually improved past the threshold
    log = rep.log
    costs = [e["result"]["cost_s"] for e in log]
    assert costs[0] == rep.baseline_cost or math.isinf(rep.baseline_cost)


@pytest.mark.parametrize("seed", range(20))
def test_sensitivity_invariants(seed):
    weights, crash = cost_surface(seed)
    runner = TrialRunner(WL, synth_evaluator(weights, crash))
    rep = run_sensitivity(runner, default_config(shard_strategy="fsdp_tp"))
    for imp in rep.impacts:
        # mean |%| is non-negative; crashes excluded from the mean
        assert imp.mean_abs_pct >= 0.0
        assert imp.crashes == sum(1 for d in imp.deviations_pct if d != d)
        # knobs with weight 1.0 everywhere have ~0 impact
        if all(weights.get((imp.knob, v), 1.0) == 1.0 for v in imp.values) \
                and not imp.crashes and imp.knob not in crash:
            assert imp.mean_abs_pct == pytest.approx(0.0, abs=1e-9)


def test_tree_beats_exhaustive_budget():
    """The whole point: <=10 trials vs the exhaustive grid."""
    assert exhaustive_size() >= 512          # paper quotes 2^9
    for kind in ("train", "prefill", "decode"):
        stages = default_tree(kind)
        n_alts = sum(len(s.alternatives) for s in stages)
        assert n_alts + 1 <= MAX_TRIALS + 1


def test_crashed_baseline_recovers():
    """If the default config crashes, any fitting config is accepted."""
    def ev(wl, rt):
        if rt.remat_policy == "dots":          # default crashes
            return TrialResult(cost_s=float("inf"), crashed=True)
        return TrialResult(cost_s=10.0)
    runner = TrialRunner(WL, ev)
    rep = run_tuning(runner, default_config(), threshold=0.05)
    assert rep.final_cost == 10.0
    assert any("memoryFraction" in a for a in rep.accepted)


def test_config_validation():
    with pytest.raises(ValueError):
        default_config(compute_dtype="float64")
    c = default_config()
    assert c.describe_delta(c.replace(microbatches=4)) == "microbatches=4"
