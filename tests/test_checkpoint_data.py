"""Checkpoint round-trip (seeded pytrees), retention/atomicity, and data
pipeline determinism / restart-exactness.

Formerly hypothesis-based; rewritten as seeded parametrized cases so the
suite has no hard dependency on `hypothesis`."""
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.core.params import default_config
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch.mesh import make_host_mesh


def _pytree(seed: int):
    """Seeded analogue of the old hypothesis pytree strategy: 1-5 keys,
    f32/i32 leaves of rank 0-3 (dims 1-5), some nested."""
    rng = np.random.RandomState(seed)
    out = {}
    for i in range(rng.randint(1, 6)):
        kind = ["f32", "i32", "nested"][rng.randint(3)]
        shp = tuple(rng.randint(1, 6, size=rng.randint(0, 4)))
        if kind == "nested":
            out[f"k{i}"] = {"a": np.ones(shp, np.float32),
                            "b": np.zeros((), np.int32)}
        else:
            dt = np.float32 if kind == "f32" else np.int32
            out[f"k{i}"] = rng.standard_normal(shp).astype(dt)
    return out


@pytest.mark.parametrize("seed,step", [(s, s * 9973 % 10**6)
                                       for s in range(20)])
def test_checkpoint_roundtrip_identity(tmp_path_factory, seed, step):
    tree = _pytree(seed)
    d = tmp_path_factory.mktemp("ck")
    ckpt.save(d, step, tree, extra={"step": step})
    restored = ckpt.restore(d, step, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.manifest_extra(d, step)["step"] == step


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, interval=1, keep=2)
    tree = {"w": jnp.arange(4.0)}
    for s in range(5):
        mgr.maybe_save(s, jax.tree.map(lambda x: x + s, tree))
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1])
                   for p in pathlib.Path(tmp_path).glob("step_*"))
    assert steps == [3, 4]
    assert mgr.latest_step() == 4
    restored, s = mgr.restore_latest(tree)
    assert s == 4
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.arange(4.0) + 4)


def test_checkpoint_tree_mismatch_raises(tmp_path):
    ckpt.save(tmp_path, 0, {"a": np.ones(3)})
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, 0, {"b": np.ones(3)})


def test_no_partial_checkpoint_visible(tmp_path):
    """Atomicity: only committed step_* dirs exist after save."""
    ckpt.save(tmp_path, 7, {"a": np.ones(3)})
    names = [p.name for p in pathlib.Path(tmp_path).iterdir()]
    assert names == ["step_00000007"]


# ---------------------------------------------------------------- data
def _source(seed=0):
    cfg = get_reduced("smollm-135m")
    shape = ShapeConfig("t", 32, 4, "train")
    return SyntheticLM(cfg, shape, default_config(), make_host_mesh(),
                       seed=seed)


def test_data_deterministic_and_restart_exact():
    s1, s2 = _source(), _source()
    b_a = s1.batch_at(5)
    b_b = s2.batch_at(5)          # fresh instance, same step -> same batch
    np.testing.assert_array_equal(np.asarray(b_a["tokens"]),
                                  np.asarray(b_b["tokens"]))
    # labels are next-token shifted
    full = np.asarray(b_a["tokens"])
    lab = np.asarray(b_a["labels"])
    assert (lab[:, :-1] == full[:, 1:]).all()


def test_data_steps_differ_and_seeds_differ():
    s = _source()
    t5 = np.asarray(s.batch_at(5)["tokens"])
    t6 = np.asarray(s.batch_at(6)["tokens"])
    assert (t5 != t6).any()
    t5b = np.asarray(_source(seed=1).batch_at(5)["tokens"])
    assert (t5 != t5b).any()


def test_prefetcher_order_and_stop():
    s = _source()
    pf = Prefetcher(s, start_step=3, depth=2)
    steps = []
    for _ in range(3):
        step, batch = next(pf)
        steps.append(step)
        np.testing.assert_array_equal(np.asarray(batch["tokens"]),
                                      np.asarray(s.batch_at(step)["tokens"]))
    pf.stop()
    assert steps == [3, 4, 5]
