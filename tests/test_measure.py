"""Measured tier: timing cache, top-k selection, the campaign's
two-tier re-rank pass, kernel cells and tile validation.

All campaign tests drive synthetic evaluators — the one real-XLA test
(the kernel-cell end-to-end) times interpret-mode Pallas at a tiny
shape.  Load-bearing invariants:

  * ``measure_top_k=0`` (the default) is a true no-op — the campaign's
    reports are bit-identical to a plain model-only run;
  * the re-rank pays at most k real evaluations per cell, publishes
    the measured winner into ``report.measured`` / the checkpoint, and
    flags when measurement overturned the model ranking;
  * the disk timing cache makes a repeat campaign's measured tier free
    (zero evaluator calls), and ``cell_done`` gates on the measured
    stamp so a finished walk still owes its re-rank;
  * a non-dividing tile knob is a clean deterministic-crash trial.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro.core.campaign import Campaign, CellSpec, parse_cells
from repro.core.history import TrialHistory
from repro.core.measure import (CachedMeasure, ReducedWallClock,
                                TimingCache, measure_key, select_top_k)
from repro.core.params import default_config
from repro.core.trial import (FAILURE_DETERMINISTIC, FAILURE_TRANSIENT,
                              TrialError, TrialResult, WallClockEvaluator,
                              Workload)

CELL = [CellSpec("smollm-135m", "train_4k")]


def baseline_factory(spec):
    return default_config(shard_strategy="fsdp_tp", attn_impl="pallas")


def model_surface(wl, rt):
    """Model cost: bf16 and remat=full both look good."""
    c = 2.0
    if rt.compute_dtype == "bfloat16":
        c *= 0.8
    if rt.remat_policy == "full":
        c *= 0.85
    if rt.microbatches == 2:
        c *= 0.95
    return TrialResult(cost_s=round(c, 6))


class TruthSurface:
    """Measured cost that disagrees: remat=full is actually slower."""

    def __init__(self):
        self.calls = []

    def __call__(self, wl, rt):
        self.calls.append((wl.key(), rt.as_dict()))
        c = 1.0
        if rt.compute_dtype == "bfloat16":
            c *= 0.8
        if rt.remat_policy == "full":
            c *= 1.5
        if rt.microbatches == 2:
            c *= 0.97
        return TrialResult(cost_s=round(c, 6), compiles=1, compile_s=0.1)


def run_campaign(tmp_path, k, truth=None, cells=CELL, **kw):
    camp = Campaign(cells, strategy="tree", checkpoint_dir=tmp_path,
                    evaluator=model_surface,
                    baseline_factory=baseline_factory,
                    measure_top_k=k,
                    measured_evaluator=truth, **kw)
    return camp, camp.run()


# ----------------------------------------------------------- selection
def test_select_top_k_dedup_and_order():
    cfg = baseline_factory(None)
    log = []
    deltas = [{"microbatches": 4}, {"microbatches": 2}, {},
              {"remat_policy": "none"}, {"compute_dtype": "bfloat16"}]
    for i, (cost, crashed) in enumerate(
            [(3.0, False), (1.0, False), (2.0, True), (1.0, False),
             (0.5, False)]):
        d = dict(cfg.as_dict(), **deltas[i])
        log.append({"name": f"t{i}", "delta": {}, "config": d,
                    "result": {"cost_s": cost, "crashed": crashed}})
    # crash skipped; i=3 distinct from others; sorted by cost
    out = select_top_k(log, 3)
    assert [c["name"] for c in out] == ["t4", "t1", "t3"]
    assert out[0]["model_cost_s"] == 0.5
    # dedup: duplicate config keeps only the first occurrence
    log.append(dict(log[1], name="dup"))
    assert [c["name"] for c in select_top_k(log, 10)] \
        == ["t4", "t1", "t3", "t0"]
    assert select_top_k([], 5) == []


# ------------------------------------------------------------- caching
def test_cached_measure_roundtrip(tmp_path):
    wl = Workload("smollm-135m", "train_4k", False)
    rt = baseline_factory(None)
    truth = TruthSurface()
    cm = CachedMeasure(truth, TimingCache(tmp_path / "t"), repeats=3)
    r1 = cm(wl, rt)
    assert not r1.cached and r1.compiles == 1 and len(truth.calls) == 1
    # same process: in-memory hit
    r2 = cm(wl, rt)
    assert r2.cached and r2.compiles == 0 and r2.cost_s == r1.cost_s
    assert len(truth.calls) == 1
    # "new process": fresh cache object over the same disk dir
    cm2 = CachedMeasure(truth, TimingCache(tmp_path / "t"), repeats=3)
    r3 = cm2(wl, rt)
    assert r3.cached and len(truth.calls) == 1
    # different repeats -> different key -> re-measured
    cm3 = CachedMeasure(truth, TimingCache(tmp_path / "t"), repeats=5)
    assert not cm3(wl, rt).cached and len(truth.calls) == 2
    assert measure_key(wl, rt, 3) != measure_key(wl, rt, 5)


def test_cached_measure_error_memo(tmp_path):
    wl = Workload("smollm-135m", "train_4k", False)
    rt = baseline_factory(None)
    calls = []

    def crasher(w, r):
        calls.append(1)
        return TrialResult(cost_s=float("inf"), crashed=True,
                           error="ValueError: boom",
                           failure=FAILURE_DETERMINISTIC, compile_s=0.2)

    cm = CachedMeasure(crasher, TimingCache(tmp_path / "t"), repeats=3)
    r1 = cm(wl, rt)
    assert r1.crashed and not r1.cached and len(calls) == 1
    # deterministic crash: memoized in-memory, replayed with its class
    r2 = cm(wl, rt)
    assert r2.crashed and r2.cached \
        and r2.failure == FAILURE_DETERMINISTIC \
        and r2.error == "ValueError: boom" and len(calls) == 1
    # ... but never persisted to disk: a fresh process re-tries
    cm2 = CachedMeasure(crasher, TimingCache(tmp_path / "t"), repeats=3)
    assert cm2(wl, rt).crashed and len(calls) == 2

    def transient(w, r):
        calls.append(1)
        return TrialResult(cost_s=float("inf"), crashed=True,
                           error="OSError: flaky",
                           failure=FAILURE_TRANSIENT)

    cmt = CachedMeasure(transient, TimingCache(tmp_path / "u"), repeats=3)
    n0 = len(calls)
    cmt(wl, rt)
    r = cmt(wl, rt)                      # transient: never memoized
    assert len(calls) == n0 + 2 and not r.cached


# ----------------------------------------- hardened WallClockEvaluator
def test_wallclock_evaluator_accounting(monkeypatch):
    from repro.launch.mesh import make_mesh
    from repro.runtime import stepfn

    @dataclasses.dataclass
    class StubBundle:
        fn: object
        args: tuple
        kind: str = "train"

    def stub_build(cfg, shape, rt, mesh):
        # (params, opt, batch) -> (params', opt', loss): the shape the
        # evaluator's donate-buffer rotation expects for kind="train"
        s = jax.ShapeDtypeStruct((8,), jnp.float32)
        return StubBundle(
            fn=jax.jit(lambda p, o, b: (p + 1.0, o + 1.0,
                                        (b * 2.0).sum())),
            args=(s, s, s))

    monkeypatch.setattr(stepfn, "build_step", stub_build)
    ev = WallClockEvaluator(lambda multi_pod=False:
                            make_mesh((1, 1), ("data", "model")),
                            repeats=3)
    res = ev(Workload("smollm-135m", "train_4k", False),
             baseline_factory(None))
    assert not res.crashed and res.cost_s > 0
    assert res.compiles == 1 and res.compile_s >= 0.0

    def exploding(cfg, shape, rt, mesh):
        raise TrialError("CacheReplay: stored crash",
                         failure=FAILURE_TRANSIENT)

    monkeypatch.setattr(stepfn, "build_step", exploding)
    res = ev(Workload("smollm-135m", "train_4k", False),
             baseline_factory(None))
    assert res.crashed and res.failure == FAILURE_TRANSIENT
    assert res.error == "CacheReplay: stored crash"  # pre-tag kept
    assert res.compiles == 0 and res.compile_s >= 0.0


def test_wallclock_rejects_nondividing_tile():
    # validation fires before any mesh/build work: 384 % 256 != 0
    ev = WallClockEvaluator(lambda multi_pod=False: None, repeats=1)

    class OddSeq(Workload):
        @property
        def shp(self):
            from repro.configs.base import ShapeConfig
            return ShapeConfig("odd", 384, 8, "train")

    res = ev(OddSeq("smollm-135m", "train_4k", False),
             baseline_factory(None).replace(attn_block_kv=256))
    assert res.crashed and res.failure == FAILURE_DETERMINISTIC
    assert "divide" in res.error


# ------------------------------------------------- campaign re-rank
def test_campaign_measured_rerank(tmp_path):
    truth = TruthSurface()
    camp, reps = run_campaign(tmp_path, 2, CachedMeasure(
        truth, TimingCache(tmp_path / "timings")))
    rep = reps[CELL[0].key()]
    md = rep.measured
    assert md["k"] == 2 and md["evaluations"] <= 2
    assert len(truth.calls) <= 2         # bounded by k
    assert md["winner"] is not None
    assert md["candidates"][0]["config"] == md["model_choice"]
    # the measured winner is the truth-cheapest candidate
    best = min((c for c in md["candidates"] if not c.get("crashed")),
               key=lambda c: c["cost_s"])
    assert md["winner"] == best["config"]
    assert md["overturned"] == (best["rank"] != 0)
    # stats + checkpoint + history all carry the measured pass
    assert camp.last_stats["measured"]["cells"] == 1
    ckpt = json.loads((tmp_path / f"{CELL[0].key()}.json").read_text())
    assert ckpt["report"]["measured"]["k"] == 2
    hist = [json.loads(l) for l in
            (tmp_path / "history.jsonl").read_text().splitlines()]
    measured_rows = [h for h in hist
                     if h.get("strategy") == "tree+measured"]
    assert len(measured_rows) == md["evaluations"]
    assert all(h["name"].startswith("measured:")
               for h in measured_rows)


def test_measure_top_k_zero_is_noop(tmp_path):
    _, plain = run_campaign(tmp_path / "a", 0)
    camp, zero = run_campaign(tmp_path / "b", 0)
    rep = zero[CELL[0].key()]
    assert rep.measured is None
    assert dataclasses.asdict(rep) == dataclasses.asdict(
        plain[CELL[0].key()])
    assert "measured" not in camp.last_stats


def test_campaign_measured_resume_and_gating(tmp_path):
    truth = TruthSurface()
    cache = TimingCache(tmp_path / "timings")
    camp1, reps1 = run_campaign(tmp_path, 2,
                                CachedMeasure(truth, cache))
    n = len(truth.calls)
    assert camp1.cell_done(CELL[0])
    # resume: walk replays, measured stamp honored, no re-measure
    camp2, reps2 = run_campaign(tmp_path, 2,
                                CachedMeasure(truth, cache))
    assert len(truth.calls) == n
    assert reps2[CELL[0].key()].measured == \
        reps1[CELL[0].key()].measured
    # a different k owes a fresh re-rank: done gate flips off
    camp3 = Campaign(CELL, strategy="tree", checkpoint_dir=tmp_path,
                     evaluator=model_surface,
                     baseline_factory=baseline_factory,
                     measure_top_k=3)
    assert not camp3.cell_done(CELL[0])
    # ... and a plain model-only campaign ignores the stamp entirely
    camp4 = Campaign(CELL, strategy="tree", checkpoint_dir=tmp_path,
                     evaluator=model_surface,
                     baseline_factory=baseline_factory)
    assert camp4.cell_done(CELL[0])


def test_measured_all_crash_keeps_model_choice(tmp_path):
    def crasher(wl, rt):
        return TrialResult(cost_s=float("inf"), crashed=True,
                           error="RuntimeError: no device",
                           failure=FAILURE_DETERMINISTIC)

    camp, reps = run_campaign(tmp_path, 2, crasher)
    md = reps[CELL[0].key()].measured
    assert md["winner"] is None and "note" in md
    assert all(c["crashed"] for c in md["candidates"])
    assert camp.cell_done(CELL[0])       # a crashed re-rank still ends


def test_sensitivity_strategy_not_measurable(tmp_path):
    truth = TruthSurface()
    camp = Campaign(CELL, strategy="sensitivity",
                    checkpoint_dir=tmp_path, evaluator=model_surface,
                    baseline_factory=baseline_factory,
                    measure_top_k=2, measured_evaluator=truth)
    camp.run()
    assert truth.calls == []             # OFAT reports have no ranking


# --------------------------------------------------------- kernel cells
def test_parse_kernel_cells():
    cells = parse_cells("kernel:flash_attention:tiny,smollm-135m:train_4k")
    assert cells[0].arch == "kernel-flash_attention"
    assert cells[0].spec() == "kernel:flash_attention:tiny"
    assert cells[1].arch == "smollm-135m"
    with pytest.raises(ValueError):
        parse_cells("kernel:nope:tiny")
    with pytest.raises(ValueError):
        parse_cells("kernel:flash_attention:nope")
    with pytest.raises(ValueError):
        parse_cells("kernel:flash_attention")


def test_kernel_cell_campaign(tmp_path):
    # real interpret-mode Pallas timing at a tiny shape: the whole
    # pipeline (stages, dispatch evaluator, checkpoint, report) runs
    cells = parse_cells("kernel:flash_decode:tiny")
    camp = Campaign(cells, strategy="tree", checkpoint_dir=tmp_path)
    reps = camp.run()
    rep = reps[cells[0].key()]
    assert rep.n_trials >= 2 and rep.baseline_cost > 0
    assert rep.final_cost <= rep.baseline_cost
    assert camp.cell_done(cells[0])


def test_kernel_bench_rejects_nondividing_tile():
    from repro.core.kernel_cell import KernelBenchEvaluator, kernel_cell
    wl = kernel_cell("flash_attention", "ragged").workload()  # S=384
    rt = baseline_factory(None).replace(attn_block_q=256)
    res = KernelBenchEvaluator(repeats=1)(wl, rt)
    assert res.crashed and res.failure == FAILURE_DETERMINISTIC
    assert "divide" in res.error


def test_space_tile_validation():
    from repro.core.space import SPACE
    rt = baseline_factory(None)
    SPACE.validate(rt)                   # no seq_len: historical path
    SPACE.validate(rt, seq_len=4096)
    SPACE.validate(rt.replace(attn_block_kv=256), seq_len=128)  # clamps
    with pytest.raises(ValueError, match="divide"):
        SPACE.validate(rt.replace(attn_block_q=256), seq_len=384)
    assert set(SPACE.seq_tile_knobs()) >= {"attn_block_q",
                                           "attn_block_kv"}


def test_reduced_wallclock_train_uses_xla_attention(monkeypatch):
    # forward-only flash kernel: the executed train proxy must swap to
    # the XLA attention path (same substitution as the roofline
    # calibration compiles) — prefill/decode keep attn_impl untouched
    seen = {}

    class SpyEv:
        repeats = 2

        def __call__(self, wl, rt):
            seen[wl.shp.kind] = rt.attn_impl
            return TrialResult(cost_s=1.0)

    ev = ReducedWallClock(repeats=2)
    ev._ev = SpyEv()
    rt = baseline_factory(None)
    ev(Workload("smollm-135m", "train_4k", False), rt)
    ev(Workload("smollm-135m", "prefill_32k", False), rt)
    assert seen == {"train": "xla", "prefill": "pallas"}
