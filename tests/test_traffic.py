"""Traffic traces (serving/traffic.py): deterministic generation,
byte-stable serialization, cross-process identity.

The serving tuner's correctness rests on every trial of every config
seeing bit-identical traffic — these tests pin that contract: same
seed -> same bytes -> same trace key, on this process and on a fresh
interpreter.
"""
import json
import subprocess
import sys

import numpy as np
import pytest

from repro.serving.traffic import (TRACE_SPECS, Tenant, Trace,
                                   TraceSpec, generate, get_trace,
                                   request_tokens, trace_names)

_TENANTS = (Tenant("chat", 0.7, (4, 12), (3, 6)),
            Tenant("batch", 0.3, (12, 24), (2, 4)))


def _spec(pattern="poisson", seed=99, n=12):
    return TraceSpec(name=f"t_{pattern}", pattern=pattern,
                     n_requests=n, mean_rate=0.5, seed=seed,
                     tenants=_TENANTS)


# ----------------------------------------------------------- determinism
def test_same_seed_same_bytes():
    a, b = generate(_spec()), generate(_spec())
    assert a.to_json() == b.to_json()
    assert a.key() == b.key()


def test_different_seed_different_bytes():
    assert generate(_spec(seed=1)).key() != generate(_spec(seed=2)).key()


@pytest.mark.parametrize("pattern", ["poisson", "bursty", "diurnal"])
def test_patterns_generate_valid_traces(pattern):
    tr = generate(_spec(pattern))
    assert len(tr.requests) == 12
    arrivals = [r.arrival_s for r in tr.requests]
    assert arrivals == sorted(arrivals)
    assert all(a > 0 for a in arrivals)
    lo = {t.name: t for t in _TENANTS}
    for r in tr.requests:
        ten = lo[r.tenant]
        assert ten.prompt_len[0] <= r.prompt_len <= ten.prompt_len[1]
        assert ten.max_new[0] <= r.max_new_tokens <= ten.max_new[1]


def test_unknown_pattern_rejected():
    with pytest.raises(ValueError, match="unknown arrival pattern"):
        generate(_spec("lunar"))


def test_empty_tenant_mix_rejected():
    with pytest.raises(ValueError, match="empty tenant mix"):
        generate(TraceSpec(name="t", pattern="poisson", n_requests=1,
                           mean_rate=1.0, seed=0, tenants=()))


def test_request_tokens_deterministic_and_bounded():
    tr = generate(_spec())
    for r in tr.requests[:4]:
        toks = request_tokens(r)
        assert toks.shape == (r.prompt_len,)
        assert toks.dtype == np.int32
        assert toks.min() >= 1          # 0 is the left-pad value
        assert toks.max() < 500
        assert np.array_equal(toks, request_tokens(r))


# --------------------------------------------------------- serialization
def test_json_roundtrip_preserves_key(tmp_path):
    tr = generate(_spec("bursty"))
    again = Trace.from_json(tr.to_json())
    assert again.key() == tr.key()
    assert again.requests == tr.requests
    path = tmp_path / "traces" / "t.json"
    tr.save(path)                        # creates the parent, atomic
    assert Trace.load(path).key() == tr.key()


def test_version_mismatch_rejected():
    doc = json.loads(generate(_spec()).to_json())
    doc["version"] = "trace-v0"
    with pytest.raises(ValueError, match="unsupported trace version"):
        Trace.from_json(json.dumps(doc))


def test_registry_traces_expand_and_memoize():
    assert set(trace_names()) == set(TRACE_SPECS)
    for name in trace_names():
        tr = get_trace(name)
        assert tr is get_trace(name)     # expanded once per process
        assert len(tr.requests) == TRACE_SPECS[name].n_requests
        assert tr.max_prompt_len() > 0
    with pytest.raises(ValueError, match="unknown trace"):
        get_trace("nope")


# -------------------------------------------------------- cross-process
@pytest.mark.slow
def test_trace_bytes_identical_across_processes():
    """A fresh interpreter serializes every registered trace to the
    same bytes — the property that lets fabric workers on different
    hosts agree on cached trial costs."""
    code = ("import hashlib, json\n"
            "from repro.serving.traffic import get_trace, trace_names\n"
            "print(json.dumps({n: [get_trace(n).key(),\n"
            "    hashlib.sha1(get_trace(n).to_json().encode())"
            ".hexdigest()]\n"
            "    for n in trace_names()}))\n")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, check=True)
    theirs = json.loads(out.stdout.strip().splitlines()[-1])
    import hashlib
    for name in trace_names():
        tr = get_trace(name)
        assert theirs[name] == [
            tr.key(),
            hashlib.sha1(tr.to_json().encode()).hexdigest()]
