#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): full test suite, fail-fast.
# Usage: scripts/verify.sh [extra pytest args], or `make verify`.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
